//! A small Rust lexer for the `recad lint` pass.
//!
//! Produces a flat token stream (idents, punctuation, literals,
//! lifetimes) with 1-based line numbers, discarding comment and string
//! *content* so rule patterns never fire on prose or log messages.
//! Comments are still inspected on the way out: `// lint:allow(<rules>)
//! <reason>` pragmas are collected with the line they annotate.
//!
//! This is not a full Rust grammar — it only needs to be faithful
//! enough that token-sequence rules (`Instant :: now`, `. unwrap (`,
//! `thread :: spawn`, `unsafe`) see the same shape rustc would, and
//! that nothing inside strings or comments leaks into the stream.
//! The tricky corners handled explicitly: nested block comments, raw
//! and byte strings (`r#"…"#`, `b"…"`, `br#"…"#`), raw identifiers
//! (`r#fn`), and char-literal vs lifetime disambiguation (`'a'` vs
//! `'a`).

/// Token kind. Literal content is dropped; only idents and punctuation
/// carry text the rules match on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Literal,
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Token {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }
    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == Kind::Ident && self.text == text
    }
}

/// A `// lint:allow(<rules>) <reason>` pragma found in a comment.
///
/// `file_level` pragmas (`lint:allow-file(...)`) suppress their rules
/// for the whole file; line pragmas cover their own line (trailing
/// form) or, when the comment stands alone, the next line that carries
/// tokens. A pragma with an empty reason is *invalid*: it suppresses
/// nothing and the rule engine reports it as a finding of its own.
#[derive(Clone, Debug)]
pub struct Pragma {
    pub line: u32,
    pub rules: Vec<String>,
    pub reason: String,
    pub file_level: bool,
    /// false when the comment contained `lint:allow` but did not parse
    /// as `lint:allow(<ids>) <reason>` — reported, never applied
    pub well_formed: bool,
}

pub struct Lexed {
    pub tokens: Vec<Token>,
    pub pragmas: Vec<Pragma>,
}

/// Multi-character punctuation, longest-match-first. Only sequences
/// the rules (or their backward scans) care to see as a unit; anything
/// else falls back to single characters, which is fine for matching.
const MULTI_PUNCT: &[&str] = &[
    "..=", "::", "->", "=>", "..", "==", "!=", "<=", ">=", "&&", "||", "<<", ">>", "+=", "-=",
    "*=", "/=",
];

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks: Vec<Token> = Vec::new();
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let n = b.len();

    macro_rules! bump_lines {
        ($slice_start:expr, $slice_end:expr) => {
            line += b[$slice_start..$slice_end].iter().filter(|&&c| c == b'\n').count() as u32;
        };
    }

    while i < n {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => {
                i += 1;
            }
            b'/' if i + 1 < n && b[i + 1] == b'/' => {
                // line comment: scan to EOL, check for a pragma
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != b'\n' {
                    j += 1;
                }
                let body = &src[start..j];
                if let Some(p) = parse_pragma(body, line) {
                    pragmas.push(p);
                }
                i = j;
            }
            b'/' if i + 1 < n && b[i + 1] == b'*' => {
                // block comment, nesting tracked
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        if b[j] == b'\n' {
                            line += 1;
                        }
                        j += 1;
                    }
                }
                i = j;
            }
            b'"' => {
                let j = scan_string(b, i);
                bump_lines!(i, j);
                toks.push(Token { kind: Kind::Literal, text: String::new(), line });
                i = j;
            }
            b'\'' => {
                // lifetime or char literal
                let (j, kind, text) = scan_quote(src, b, i);
                toks.push(Token { kind, text, line });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let j = scan_number(b, i);
                toks.push(Token { kind: Kind::Literal, text: String::new(), line });
                i = j;
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let mut j = i + 1;
                while j < n && (b[j] == b'_' || b[j].is_ascii_alphanumeric()) {
                    j += 1;
                }
                let ident = &src[i..j];
                // raw strings / byte strings start with these prefixes
                if (ident == "r" || ident == "b" || ident == "br") && j < n {
                    if b[j] == b'"' {
                        let raw = ident != "b"; // b"…" is an escaped byte string
                        let e = if raw { scan_raw_string(b, j, 0) } else { scan_string(b, j) };
                        bump_lines!(j, e);
                        toks.push(Token { kind: Kind::Literal, text: String::new(), line });
                        i = e;
                        continue;
                    }
                    if b[j] == b'#' {
                        let mut hashes = 0usize;
                        let mut k = j;
                        while k < n && b[k] == b'#' {
                            hashes += 1;
                            k += 1;
                        }
                        if k < n && b[k] == b'"' {
                            let e = scan_raw_string(b, k, hashes);
                            bump_lines!(j, e);
                            toks.push(Token { kind: Kind::Literal, text: String::new(), line });
                            i = e;
                            continue;
                        }
                        if ident == "r" {
                            // raw identifier r#ident
                            let mut e = k;
                            while e < n && (b[e] == b'_' || b[e].is_ascii_alphanumeric()) {
                                e += 1;
                            }
                            toks.push(Token {
                                kind: Kind::Ident,
                                text: src[k..e].to_string(),
                                line,
                            });
                            i = e;
                            continue;
                        }
                    }
                }
                toks.push(Token { kind: Kind::Ident, text: ident.to_string(), line });
                i = j;
            }
            _ => {
                let rest = &src[i..];
                let mut matched = false;
                for p in MULTI_PUNCT {
                    if rest.starts_with(p) {
                        toks.push(Token { kind: Kind::Punct, text: p.to_string(), line });
                        i += p.len();
                        matched = true;
                        break;
                    }
                }
                if !matched {
                    toks.push(Token {
                        kind: Kind::Punct,
                        text: (c as char).to_string(),
                        line,
                    });
                    i += 1;
                }
            }
        }
    }
    Lexed { tokens: toks, pragmas }
}

/// Scan a `"…"` string starting at the opening quote; returns the index
/// one past the closing quote. Backslash escapes are honored.
fn scan_string(b: &[u8], start: usize) -> usize {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

/// Scan a raw string whose opening `"` is at `start`, closed by `"`
/// followed by `hashes` `#` characters. No escapes.
fn scan_raw_string(b: &[u8], start: usize, hashes: usize) -> usize {
    let n = b.len();
    let mut j = start + 1;
    while j < n {
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return k;
            }
        }
        j += 1;
    }
    n
}

/// Number literal: digits plus `_`, type suffixes, hex/bin alpha, a
/// fractional dot (but not `..` ranges) and exponent signs.
fn scan_number(b: &[u8], start: usize) -> usize {
    let n = b.len();
    let mut j = start;
    while j < n {
        let c = b[j];
        if c == b'_' || c.is_ascii_alphanumeric() {
            // exponent sign: 1e-3 / 1E+3
            if (c == b'e' || c == b'E')
                && j + 1 < n
                && (b[j + 1] == b'+' || b[j + 1] == b'-')
                && j > start
                && b[start] != b'0' // not hex 0xE...
            {
                j += 2;
                continue;
            }
            j += 1;
        } else if c == b'.' {
            // `1.5` continues the literal, `0..n` does not
            if j + 1 < n && b[j + 1] == b'.' {
                return j;
            }
            if j + 1 < n && b[j + 1].is_ascii_digit() {
                j += 1;
            } else {
                return j;
            }
        } else {
            return j;
        }
    }
    j
}

/// `'` disambiguation: `'a` lifetime (kept, rules never match it but
/// the backward scans must not be confused) vs `'x'` / `'\n'` char
/// literal.
fn scan_quote(src: &str, b: &[u8], start: usize) -> (usize, Kind, String) {
    let n = b.len();
    let j = start + 1;
    if j < n && (b[j] == b'_' || b[j].is_ascii_alphabetic()) {
        // run of ident chars; a closing quote right after means char
        let mut k = j;
        while k < n && (b[k] == b'_' || b[k].is_ascii_alphanumeric()) {
            k += 1;
        }
        if k < n && b[k] == b'\'' {
            return (k + 1, Kind::Literal, String::new());
        }
        return (k, Kind::Lifetime, src[j..k].to_string());
    }
    if j < n && b[j] == b'\\' {
        // escaped char literal: scan to closing quote
        let mut k = j + 1;
        while k < n && b[k] != b'\'' {
            k += 1;
        }
        return ((k + 1).min(n), Kind::Literal, String::new());
    }
    // plain char literal like '+' or unterminated garbage
    let mut k = j;
    while k < n && b[k] != b'\'' && b[k] != b'\n' {
        k += 1;
    }
    if k < n && b[k] == b'\'' {
        (k + 1, Kind::Literal, String::new())
    } else {
        (j, Kind::Punct, "'".to_string())
    }
}

/// Parse a pragma out of a line-comment body. Returns None when the
/// comment has nothing to do with lint pragmas.
fn parse_pragma(body: &str, line: u32) -> Option<Pragma> {
    let t = body.trim_start();
    if !t.starts_with("lint:allow") {
        return None;
    }
    let rest = &t["lint:allow".len()..];
    let (file_level, rest) = match rest.strip_prefix("-file") {
        Some(r) => (true, r),
        None => (false, rest),
    };
    let malformed = |reason: &str| Pragma {
        line,
        rules: Vec::new(),
        reason: reason.to_string(),
        file_level,
        well_formed: false,
    };
    let Some(rest) = rest.trim_start().strip_prefix('(') else {
        return Some(malformed("missing rule list"));
    };
    let Some(close) = rest.find(')') else {
        return Some(malformed("unterminated rule list"));
    };
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let reason = rest[close + 1..].trim().to_string();
    if rules.is_empty() {
        return Some(malformed("empty rule list"));
    }
    Some(Pragma { line, rules, reason, file_level, well_formed: true })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == Kind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r##"
            // Instant::now in a comment
            /* unwrap() in /* nested */ block */
            let s = "thread::spawn(HashMap)";
            let r = r#"unsafe "quoted" text"#;
            let b = b"panic!";
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        for bad in ["Instant", "unwrap", "spawn", "HashMap", "unsafe", "panic"] {
            assert!(!ids.contains(&bad.to_string()), "leaked {bad}");
        }
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }").tokens;
        let lifetimes: Vec<_> =
            toks.iter().filter(|t| t.kind == Kind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(lifetimes[0].text, "a");
        let lits = toks.iter().filter(|t| t.kind == Kind::Literal).count();
        assert_eq!(lits, 2);
    }

    #[test]
    fn multi_punct_and_lines() {
        let toks = lex("a::b\n->c\nd..e").tokens;
        let t: Vec<(&str, u32)> =
            toks.iter().map(|t| (t.text.as_str(), t.line)).collect();
        assert!(t.contains(&("::", 1)));
        assert!(t.contains(&("->", 2)));
        assert!(t.contains(&("..", 3)));
        assert_eq!(toks.last().unwrap().line, 3);
    }

    #[test]
    fn ranges_do_not_eat_numbers() {
        let toks = lex("for i in 0..10 { x[i] = 1.5e-3; }").tokens;
        let puncts: Vec<_> =
            toks.iter().filter(|t| t.text == "..").collect();
        assert_eq!(puncts.len(), 1);
        let lits = toks.iter().filter(|t| t.kind == Kind::Literal).count();
        assert_eq!(lits, 3); // 0, 10, 1.5e-3
    }

    #[test]
    fn pragma_parsing() {
        let lx = lex("// lint:allow(D1, D2) iteration feeds a sort\nfoo();");
        assert_eq!(lx.pragmas.len(), 1);
        let p = &lx.pragmas[0];
        assert!(p.well_formed && !p.file_level);
        assert_eq!(p.rules, vec!["D1".to_string(), "D2".to_string()]);
        assert_eq!(p.reason, "iteration feeds a sort");

        let lx = lex("// lint:allow-file(D2) wall-clock by design");
        assert!(lx.pragmas[0].file_level);

        let lx = lex("// lint:allow(D1)"); // no reason: well-formed but empty reason
        assert!(lx.pragmas[0].well_formed);
        assert!(lx.pragmas[0].reason.is_empty());

        let lx = lex("// lint:allow D1 forgot parens");
        assert!(!lx.pragmas[0].well_formed);
    }

    #[test]
    fn raw_identifiers() {
        let ids = idents("let r#fn = 1; r#match(r#fn);");
        assert_eq!(ids, vec!["let", "fn", "match", "fn"]);
    }
}
