//! `recad lint` — a self-hosted, zero-dependency determinism &
//! robustness analysis pass over this crate's own source.
//!
//! Every performance layer in this repo is only trustworthy because
//! its tests pin bit-identity, and bit-identity rests on invariants
//! the compiler does not check: no HashMap-iteration-order leaks into
//! results, wall-clock only behind `util/clock`, seeded splitmix64 for
//! every random verdict, no panic paths in request serving, no
//! unsupervised threads. Those invariants have been violated and
//! patched reactively before (reorder canonicalization, serve
//! requeue-on-unwind); this module enforces them statically so the
//! next concurrency-heavy subsystem cannot regress them silently.
//!
//! Pipeline: `lexer` turns each file into a token stream (comments and
//! string contents dropped; `// lint:allow(...)` pragmas collected),
//! `walk` finds test-code spans, `rules` runs the D1–D6 patterns and
//! applies pragmas, `report` renders human/JSON output. `run_lint`
//! drives the whole pass over `src/**`, `tests/**`, `examples/**`
//! (sorted traversal — the lint output itself is deterministic).

pub mod lexer;
pub mod report;
pub mod rules;
pub mod walk;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::analysis::rules::{lint_file, Finding};

/// Allowlist roots per rule. Paths are relative to the crate root,
/// '/'-separated; a root is a plain prefix (`src/net/` covers the
/// directory, `src/util/clock.rs` the file). The `[lint]` config
/// section *extends* these defaults — the baked-in roots are part of
/// the invariant, not a suggestion.
#[derive(Clone, Debug)]
pub struct LintCfg {
    /// D2: files allowed to read the wall clock directly
    pub allow_instant: Vec<String>,
    /// D3: request-path roots where panicking is banned
    pub request_paths: Vec<String>,
    /// D4: roots allowed to spawn raw threads
    pub allow_spawn: Vec<String>,
    /// also flag valid pragmas that suppress nothing (off by default:
    /// useful locally, too brittle for a cross-version CI gate)
    pub strict_pragmas: bool,
}

impl Default for LintCfg {
    fn default() -> LintCfg {
        LintCfg {
            allow_instant: vec![
                "src/util/clock.rs".into(),
                "src/util/bench.rs".into(),
                "src/bench_support.rs".into(),
                "examples/".into(),
            ],
            request_paths: vec!["src/net/".into(), "src/serve/".into()],
            allow_spawn: vec![
                "src/exec/".into(),
                "src/serve/server.rs".into(),
                "src/reorder/online.rs".into(),
            ],
            strict_pragmas: false,
        }
    }
}

impl LintCfg {
    /// Config for linting standalone fixture snippets: every rule is
    /// in scope regardless of path (fixtures live outside `src/`).
    pub fn fixture() -> LintCfg {
        LintCfg {
            allow_instant: Vec::new(),
            request_paths: vec!["".into()],
            allow_spawn: Vec::new(),
            strict_pragmas: false,
        }
    }
}

/// Result of a full lint pass.
pub struct LintRun {
    /// files scanned
    pub files: usize,
    /// findings after pragma suppression (plus pragma-misuse findings)
    pub findings: Vec<Finding>,
    /// rule hits before pragmas were applied
    pub findings_raw: usize,
    /// findings suppressed by a valid pragma
    pub suppressed: usize,
}

impl LintRun {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Lint a single source text. `path` is the normalized relative path
/// used for rule scoping and reporting; fixtures pass a synthetic one.
pub fn lint_source(
    path: &str,
    src: &str,
    cfg: &LintCfg,
    only: Option<&str>,
) -> rules::FileFindings {
    let lexed = lexer::lex(src);
    let mut ff = lint_file(path, &lexed, cfg, only);
    if let Some(rule) = only {
        // a rule filter also filters pragma-misuse noise to that rule's
        // pragmas; simplest faithful form: keep only the chosen rule
        ff.after.retain(|f| f.rule == rule);
    }
    ff
}

/// Run the full pass over `{root}/src`, `{root}/tests`,
/// `{root}/examples`. `root` is the crate root (the directory holding
/// `src/`).
pub fn run_lint(root: &Path, cfg: &LintCfg, only: Option<&str>) -> Result<LintRun> {
    let mut files: Vec<PathBuf> = Vec::new();
    for sub in ["src", "tests", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut files)?;
        }
    }
    files.sort();
    let mut run = LintRun { files: 0, findings: Vec::new(), findings_raw: 0, suppressed: 0 };
    for f in &files {
        let src = fs::read_to_string(f)
            .with_context(|| format!("lint: reading {}", f.display()))?;
        let rel = rel_path(root, f);
        let ff = lint_source(&rel, &src, cfg, only);
        run.files += 1;
        run.findings_raw += ff.raw;
        run.suppressed += ff.suppressed;
        run.findings.extend(ff.after);
    }
    run.findings.sort();
    Ok(run)
}

/// Recursively collect `.rs` files, skipping `lint_fixtures/` (known-
/// bad snippets exercised explicitly by `tests/lint.rs`) and `target/`.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = fs::read_dir(dir)
        .with_context(|| format!("lint: walking {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if p.is_dir() {
            if name == "lint_fixtures" || name == "target" {
                continue;
            }
            collect_rs(&p, out)?;
        } else if name.ends_with(".rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, file: &Path) -> String {
    let rel = file.strip_prefix(root).unwrap_or(file);
    let parts: Vec<String> = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    parts.join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_cfg_scopes() {
        let cfg = LintCfg::default();
        assert!(rules::path_allowed("src/util/clock.rs", &cfg.allow_instant));
        assert!(rules::path_allowed("examples/perf_probe.rs", &cfg.allow_instant));
        assert!(!rules::path_allowed("src/serve/server.rs", &cfg.allow_instant));
        assert!(rules::path_allowed("src/net/router.rs", &cfg.request_paths));
        assert!(!rules::path_allowed("src/tt/table.rs", &cfg.request_paths));
        assert!(rules::path_allowed("src/exec/pool.rs", &cfg.allow_spawn));
    }

    #[test]
    fn lint_source_flags_and_filters() {
        let bad = "fn f() { let t = std::time::Instant::now(); t.elapsed(); }\n";
        let ff = lint_source("src/x.rs", bad, &LintCfg::default(), None);
        assert_eq!(ff.after.len(), 1);
        assert_eq!(ff.after[0].rule, "D2");
        // rule filter excludes it
        let ff = lint_source("src/x.rs", bad, &LintCfg::default(), Some("D1"));
        assert!(ff.after.is_empty());
        // allowlisted path excludes it
        let ff = lint_source("src/util/clock.rs", bad, &LintCfg::default(), None);
        assert!(ff.after.is_empty());
    }

    #[test]
    fn pragma_suppresses_with_reason_only() {
        let cfg = LintCfg::default();
        let with_reason =
            "fn f() { let t = Instant::now(); } // lint:allow(D2) bench timing only\n";
        let ff = lint_source("src/x.rs", with_reason, &cfg, None);
        assert!(ff.after.is_empty(), "{:?}", ff.after);
        assert_eq!(ff.raw, 1);
        assert_eq!(ff.suppressed, 1);

        let no_reason = "fn f() { let t = Instant::now(); } // lint:allow(D2)\n";
        let ff = lint_source("src/x.rs", no_reason, &cfg, None);
        // the D2 finding survives AND the empty pragma is reported
        assert_eq!(ff.after.len(), 2, "{:?}", ff.after);
        assert!(ff.after.iter().any(|f| f.rule == "D2"));
        assert!(ff.after.iter().any(|f| f.rule == "pragma"));
    }

    #[test]
    fn file_level_pragma_covers_all_lines() {
        let cfg = LintCfg::default();
        let src = "\
// lint:allow-file(D2) this module times sockets; wall-clock by design
fn a() { let t = Instant::now(); }
fn b() { let t = Instant::now(); }
";
        let ff = lint_source("src/x.rs", src, &cfg, None);
        assert!(ff.after.is_empty(), "{:?}", ff.after);
        assert_eq!(ff.suppressed, 2);
    }
}
