//! Module/scope walking over the token stream: finds `#[cfg(test)]
//! mod … { … }` bodies and `#[test] fn … { … }` bodies so rules that
//! only guard production paths (D2/D3/D4) can skip test code.
//!
//! Works purely on the lexed token stream — brace depth matching, no
//! AST. Attribute chains between the marker attribute and the item
//! (`#[should_panic]`, `#[ignore]`, visibility modifiers) are skipped.

use crate::analysis::lexer::Token;

/// Inclusive line span `[start, end]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub start: u32,
    pub end: u32,
}

impl Span {
    pub fn contains(&self, line: u32) -> bool {
        line >= self.start && line <= self.end
    }
}

/// Collect line spans of test-only code: `#[cfg(test)]` items with a
/// brace body, and `#[test]` functions.
pub fn test_spans(toks: &[Token]) -> Vec<Span> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if let Some(after) = match_attr(toks, i) {
            if let Some(span) = item_body_span(toks, after, toks[i].line) {
                spans.push(span);
                // nested #[test] fns inside a cfg(test) mod are already
                // covered; keep scanning from inside anyway (cheap, and
                // overlapping spans are harmless)
            }
        }
        i += 1;
    }
    spans
}

/// Does an attribute starting at `i` mark test code? Matches
/// `# [ cfg ( test ) ]` and `# [ test ]`. Returns the index one past
/// the closing `]` on a match.
fn match_attr(toks: &[Token], i: usize) -> Option<usize> {
    if !toks.get(i)?.is("#") || !toks.get(i + 1)?.is("[") {
        return None;
    }
    let t2 = toks.get(i + 2)?;
    if t2.is_ident("test") && toks.get(i + 3)?.is("]") {
        return Some(i + 4);
    }
    if t2.is_ident("cfg")
        && toks.get(i + 3)?.is("(")
        && toks.get(i + 4)?.is_ident("test")
        && toks.get(i + 5)?.is(")")
        && toks.get(i + 6)?.is("]")
    {
        return Some(i + 7);
    }
    None
}

/// From the token after a test attribute, skip further attributes and
/// modifiers, then find the item's `{ … }` body and return its span.
/// Items without a brace body (`#[cfg(test)] use …;`, `mod tests;`)
/// return None.
fn item_body_span(toks: &[Token], mut i: usize, attr_line: u32) -> Option<Span> {
    // skip stacked attributes: # [ … ] with bracket depth matching
    while toks.get(i)?.is("#") && toks.get(i + 1).map(|t| t.is("[")).unwrap_or(false) {
        let mut depth = 0i32;
        i += 1;
        loop {
            let t = toks.get(i)?;
            if t.is("[") {
                depth += 1;
            } else if t.is("]") {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            i += 1;
        }
    }
    // scan forward to the opening brace, bailing at a `;` (bodyless
    // item) or implausibly far (not an item we understand)
    let open = {
        let mut j = i;
        let mut found = None;
        // generics/where clauses can hold `{` only inside const generics
        // braces are rare there; a simple first-`{` scan with a bound
        // works for this crate's shapes
        while j < toks.len() && j < i + 64 {
            let t = &toks[j];
            if t.is(";") {
                return None;
            }
            if t.is("{") {
                found = Some(j);
                break;
            }
            j += 1;
        }
        found?
    };
    // match the brace
    let mut depth = 0i32;
    let mut j = open;
    while j < toks.len() {
        let t = &toks[j];
        if t.is("{") {
            depth += 1;
        } else if t.is("}") {
            depth -= 1;
            if depth == 0 {
                return Some(Span { start: attr_line, end: t.line });
            }
        }
        j += 1;
    }
    // unbalanced (truncated file): cover to EOF
    Some(Span { start: attr_line, end: toks.last().map(|t| t.line).unwrap_or(attr_line) })
}

/// True when `line` falls inside any of the collected test spans.
pub fn in_test_span(spans: &[Span], line: u32) -> bool {
    spans.iter().any(|s| s.contains(line))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    #[test]
    fn cfg_test_mod_span() {
        let src = "\
fn prod() {}
#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn t() { prod(); }
}
fn after() {}
";
        let toks = lex(src).tokens;
        let spans = test_spans(&toks);
        assert!(in_test_span(&spans, 3));
        assert!(in_test_span(&spans, 6));
        assert!(!in_test_span(&spans, 1));
        assert!(!in_test_span(&spans, 8));
    }

    #[test]
    fn test_fn_with_extra_attrs() {
        let src = "\
#[test]
#[should_panic(expected = \"boom\")]
fn explodes() {
    panic!(\"boom\");
}
fn helper() {}
";
        let toks = lex(src).tokens;
        let spans = test_spans(&toks);
        assert!(in_test_span(&spans, 4));
        assert!(!in_test_span(&spans, 6));
    }

    #[test]
    fn bodyless_cfg_test_items_ignored() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn prod() {}\n";
        let toks = lex(src).tokens;
        let spans = test_spans(&toks);
        assert!(!in_test_span(&spans, 3));
    }
}
