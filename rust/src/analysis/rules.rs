//! The six determinism & robustness rules, run over a lexed file.
//!
//! Each rule is a token-sequence pattern wired to a failure mode this
//! repo has actually shipped and fixed reactively (see README "Static
//! analysis" for the rule table):
//!
//! - **D1** — HashMap/HashSet iteration: order-dependent results leak
//!   into f64 accumulation order and tie-breaks (the PR 3 reorder bug).
//! - **D2** — `Instant::now` / `SystemTime` outside `util/clock` and
//!   bench code: untestable wall-clock timing.
//! - **D3** — `.unwrap()` / `.expect()` / `panic!` / `unreachable!` on
//!   `net/` + `serve/` request paths: a poisoned mutex or severed
//!   channel must degrade (shed/requeue), not unwind.
//! - **D4** — raw `thread::spawn` outside `exec/`, the serve
//!   supervisor, and `reorder/online.rs`: unsupervised threads escape
//!   the fault plan.
//! - **D5** — nondeterministic randomness (`thread_rng`-style,
//!   `RandomState`, `DefaultHasher`): everything must come from the
//!   seeded splitmix64 domain.
//! - **D6** — `unsafe`: every occurrence needs a pragma with a written
//!   justification (the simd kernels carry theirs).
//!
//! D1 is necessarily a heuristic (no type inference): it tracks, per
//! file, identifiers whose declaration or initializer names
//! `HashMap`/`HashSet` — through wrapper types like `Arc<Mutex<…>>` —
//! and flags iteration-order-revealing method calls (`.iter()`,
//! `.keys()`, `.values()`, `.drain()`, `.retain()`, …) and
//! `for … in &ident` loops on them. Vec-of-map bindings
//! (`Vec<HashMap<…>>`) are flagged only when the receiver is indexed
//! (`adj[v].values()`), since iterating the outer Vec is ordered.
//! Cross-file flows (a map returned by another module) are out of
//! scope; the crate-level invariant is enforced where maps are born.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::lexer::{Kind, Lexed, Pragma, Token};
use crate::analysis::walk::{in_test_span, test_spans, Span};
use crate::analysis::LintCfg;

/// Rule ids with one-line invariants, in report order.
pub const RULES: &[(&str, &str)] = &[
    ("D1", "no HashMap/HashSet iteration outside pragma'd order-independent uses"),
    ("D2", "wall-clock (Instant::now/SystemTime) only behind util/clock + bench code"),
    ("D3", "no unwrap/expect/panic!/unreachable! on net/ + serve/ request paths"),
    ("D4", "no raw thread::spawn outside exec/, the serve supervisor, reorder/online"),
    ("D5", "no nondeterministic randomness; splitmix64 is the only entropy source"),
    ("D6", "every unsafe block carries a lint:allow(D6) justification"),
];

#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub message: String,
}

/// Outcome of linting one file.
pub struct FileFindings {
    /// findings that survive pragma suppression, plus pragma-misuse
    /// findings (rule id "pragma")
    pub after: Vec<Finding>,
    /// rule findings before any pragma was applied
    pub raw: usize,
    /// findings suppressed by a valid pragma
    pub suppressed: usize,
}

/// Lint one already-lexed file. `only` restricts to a single rule id.
pub fn lint_file(path: &str, lexed: &Lexed, cfg: &LintCfg, only: Option<&str>) -> FileFindings {
    let toks = &lexed.tokens;
    let spans = test_spans(toks);
    let mut raw: Vec<Finding> = Vec::new();

    let want = |rule: &str| only.map(|o| o == rule).unwrap_or(true);

    if want("D1") {
        rule_d1(path, toks, &mut raw);
    }
    if want("D2") && !path_allowed(path, &cfg.allow_instant) {
        rule_d2(path, toks, &spans, &mut raw);
    }
    if want("D3") && path_allowed(path, &cfg.request_paths) {
        rule_d3(path, toks, &spans, &mut raw);
    }
    if want("D4") && path.starts_with("src/") && !path_allowed(path, &cfg.allow_spawn) {
        rule_d4(path, toks, &spans, &mut raw);
    }
    if want("D5") {
        rule_d5(path, toks, &mut raw);
    }
    if want("D6") {
        rule_d6(path, toks, &mut raw);
    }
    raw.sort();

    apply_pragmas(path, raw, &lexed.pragmas, toks, cfg, only)
}

/// True when `path` (normalized, '/'-separated, relative) falls under
/// any allowlist root. Roots are plain prefixes: `src/net/` covers the
/// directory, `src/util/clock.rs` covers the file.
pub fn path_allowed(path: &str, roots: &[String]) -> bool {
    roots.iter().any(|r| !r.is_empty() && path.starts_with(r.as_str()))
}

// ---------------------------------------------------------------- D1

const D1_METHODS: &[&str] = &[
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "into_keys",
    "into_values", "drain", "retain", "retain_mut",
];

/// Punctuation the backward declaration scan steps over: generics,
/// references, grouping, paths, macro bangs.
const D1_SKIP_PUNCT: &[&str] = &["<", ">", ">>", "&", "(", ")", "[", "]", "::", ",", "!", ";"];

/// Wrapper/path idents the scan steps over between the hash type and
/// its binder: `x: Arc<Mutex<HashMap<…>>>`, `= Some(HashMap::new())`.
const D1_SKIP_IDENT: &[&str] = &[
    "mut", "dyn", "Arc", "Rc", "Mutex", "RwLock", "Option", "Box", "RefCell", "Cell",
    "Some", "std", "sync", "collections", "new", "with_capacity", "default", "from",
];

/// Idents/punct that mark the binding as vec-of-map rather than a map.
const D1_VEC_MARKERS: &[&str] = &["Vec", "VecDeque", "vec"];

fn rule_d1(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    // pass 1: collect hash-typed bindings (ident -> is_vec_of)
    let mut bindings: BTreeMap<String, bool> = BTreeMap::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        let mut vec_of = false;
        let mut j = i;
        let mut steps = 0;
        let binder = loop {
            if j == 0 || steps > 24 {
                break None;
            }
            j -= 1;
            steps += 1;
            let t = &toks[j];
            match t.kind {
                Kind::Punct if t.is(":") || t.is("=") => {
                    break match toks.get(j.wrapping_sub(1)) {
                        Some(p) if j >= 1 && p.kind == Kind::Ident && !is_keyword(&p.text) => {
                            Some(p.text.clone())
                        }
                        _ => None,
                    };
                }
                Kind::Punct if t.is("[") => {
                    vec_of = true;
                }
                Kind::Punct if D1_SKIP_PUNCT.contains(&t.text.as_str()) => {}
                Kind::Ident if D1_VEC_MARKERS.contains(&t.text.as_str()) => {
                    vec_of = true;
                }
                Kind::Ident if D1_SKIP_IDENT.contains(&t.text.as_str()) => {}
                Kind::Lifetime => {}
                _ => break None,
            }
        };
        if let Some(name) = binder {
            // a direct binding anywhere in the file outranks vec-of
            let e = bindings.entry(name).or_insert(vec_of);
            *e = *e && vec_of;
        }
    }
    if bindings.is_empty() {
        return;
    }

    // pass 2a: iteration-method calls, walking the receiver chain
    for i in 1..toks.len() {
        if toks[i].kind != Kind::Ident
            || !D1_METHODS.contains(&toks[i].text.as_str())
            || !toks[i - 1].is(".")
            || !toks.get(i + 1).map(|t| t.is("(")).unwrap_or(false)
        {
            continue;
        }
        for (name, indexed) in receiver_idents(toks, i - 1) {
            if let Some(&vec_of) = bindings.get(&name) {
                if !vec_of || indexed {
                    out.push(Finding {
                        file: path.to_string(),
                        line: toks[i].line,
                        rule: "D1".into(),
                        message: format!(
                            "iteration over hash-ordered `{name}` via .{}() — order-dependent",
                            toks[i].text
                        ),
                    });
                }
            }
        }
    }

    // pass 2b: `for … in [&][mut] ident {` loops
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_ident("for") {
            // find the `in` of this loop header (bounded scan)
            let mut k = i + 1;
            let mut found_in = None;
            while k < toks.len() && k < i + 24 {
                if toks[k].is_ident("in") {
                    found_in = Some(k);
                    break;
                }
                if toks[k].is("{") {
                    break;
                }
                k += 1;
            }
            if let Some(inpos) = found_in {
                // expr tokens until `{`
                let mut expr: Vec<&Token> = Vec::new();
                let mut k = inpos + 1;
                while k < toks.len() && k < inpos + 8 && !toks[k].is("{") {
                    expr.push(&toks[k]);
                    k += 1;
                }
                let idents: Vec<&&Token> =
                    expr.iter().filter(|t| t.kind == Kind::Ident && !t.is_ident("mut")).collect();
                let only_ref = expr
                    .iter()
                    .all(|t| t.kind == Kind::Ident || t.is("&") || t.is("*"));
                if only_ref && idents.len() == 1 {
                    let name = &idents[0].text;
                    if let Some(&vec_of) = bindings.get(name.as_str()) {
                        if !vec_of {
                            out.push(Finding {
                                file: path.to_string(),
                                line: toks[i].line,
                                rule: "D1".into(),
                                message: format!(
                                    "for-loop over hash-ordered `{name}` — order-dependent"
                                ),
                            });
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "let" | "mut" | "ref" | "static" | "const" | "pub" | "fn" | "in" | "if" | "else"
            | "match" | "return" | "move" | "use" | "type" | "where"
    )
}

/// Walk a method-call receiver chain backward from the `.` at `dot`,
/// collecting every identifier in the chain with a flag for whether it
/// was indexed (`ident[…]`). `a.b[i].c().iter()` yields c, b (indexed),
/// a.
fn receiver_idents(toks: &[Token], dot: usize) -> Vec<(String, bool)> {
    let mut names = Vec::new();
    if dot == 0 {
        return names;
    }
    let mut j = dot - 1;
    let mut indexed_next = false;
    loop {
        let t = &toks[j];
        if t.is(")") || t.is("]") {
            if t.is("]") {
                indexed_next = true;
            }
            let (open, close) = if t.is(")") { ("(", ")") } else { ("[", "]") };
            let mut depth = 0i32;
            loop {
                let u = &toks[j];
                if u.is(close) {
                    depth += 1;
                } else if u.is(open) {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                if j == 0 {
                    return names;
                }
                j -= 1;
            }
            if j == 0 {
                return names;
            }
            j -= 1;
            continue;
        }
        if t.is("?") {
            if j == 0 {
                return names;
            }
            j -= 1;
            continue;
        }
        if t.kind == Kind::Ident {
            names.push((t.text.clone(), indexed_next));
            indexed_next = false;
            if j >= 1 && (toks[j - 1].is(".") || toks[j - 1].is("::")) {
                if j < 2 {
                    return names;
                }
                j -= 2;
                continue;
            }
        }
        return names;
    }
}

// ---------------------------------------------------------------- D2

fn rule_d2(path: &str, toks: &[Token], spans: &[Span], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        let hit = (toks[i].is_ident("Instant")
            && toks.get(i + 1).map(|t| t.is("::")).unwrap_or(false)
            && toks.get(i + 2).map(|t| t.is_ident("now")).unwrap_or(false))
            || toks[i].is_ident("SystemTime");
        if hit && !in_test_span(spans, toks[i].line) {
            out.push(Finding {
                file: path.to_string(),
                line: toks[i].line,
                rule: "D2".into(),
                message: "wall-clock read outside util/clock — untestable timing; \
                          inject a Clock"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------- D3

fn rule_d3(path: &str, toks: &[Token], spans: &[Span], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        let t = &toks[i];
        let line = t.line;
        if in_test_span(spans, line) {
            continue;
        }
        let method_panic = i >= 1
            && toks[i - 1].is(".")
            && (t.is_ident("unwrap") || t.is_ident("expect"))
            && toks.get(i + 1).map(|u| u.is("(")).unwrap_or(false);
        let macro_panic = (t.is_ident("panic")
            || t.is_ident("unreachable")
            || t.is_ident("todo")
            || t.is_ident("unimplemented"))
            && toks.get(i + 1).map(|u| u.is("!")).unwrap_or(false);
        if method_panic || macro_panic {
            out.push(Finding {
                file: path.to_string(),
                line,
                rule: "D3".into(),
                message: format!(
                    "`{}` on a request path — poisoned locks / severed channels must \
                     shed or requeue, not unwind",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- D4

fn rule_d4(path: &str, toks: &[Token], spans: &[Span], out: &mut Vec<Finding>) {
    for i in 0..toks.len() {
        if toks[i].is_ident("thread")
            && toks.get(i + 1).map(|t| t.is("::")).unwrap_or(false)
            && toks.get(i + 2).map(|t| t.is_ident("spawn")).unwrap_or(false)
            && !in_test_span(spans, toks[i].line)
        {
            out.push(Finding {
                file: path.to_string(),
                line: toks[i].line,
                rule: "D4".into(),
                message: "raw thread::spawn outside the supervised roots — escapes \
                          the fault plan"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------- D5

const D5_BANNED: &[&str] = &["thread_rng", "ThreadRng", "from_entropy", "RandomState", "DefaultHasher"];

fn rule_d5(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    for t in toks {
        if t.kind == Kind::Ident && D5_BANNED.contains(&t.text.as_str()) {
            out.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "D5".into(),
                message: format!(
                    "`{}` is nondeterministic — use util::prng (splitmix64) instead",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- D6

fn rule_d6(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    for t in toks {
        if t.is_ident("unsafe") {
            out.push(Finding {
                file: path.to_string(),
                line: t.line,
                rule: "D6".into(),
                message: "unsafe requires a lint:allow(D6) pragma with a written \
                          justification"
                    .into(),
            });
        }
    }
}

// -------------------------------------------------------- pragmas

/// Apply pragmas to raw findings. Valid pragmas (well-formed, with a
/// reason) suppress matching rules on their covered lines; invalid
/// pragmas suppress nothing and are themselves reported under the
/// synthetic rule id "pragma".
fn apply_pragmas(
    path: &str,
    raw: Vec<Finding>,
    pragmas: &[Pragma],
    toks: &[Token],
    cfg: &LintCfg,
    only: Option<&str>,
) -> FileFindings {
    let token_lines: BTreeSet<u32> = toks.iter().map(|t| t.line).collect();
    let raw_count = raw.len();

    // (rule, covered line) for line pragmas; rule for file pragmas
    struct Active<'a> {
        p: &'a Pragma,
        lines: Option<(u32, u32)>, // None = whole file; else the two candidate lines
        used: bool,
    }
    let mut active: Vec<Active> = Vec::new();
    let mut pragma_findings: Vec<Finding> = Vec::new();
    for p in pragmas {
        if !p.well_formed {
            pragma_findings.push(Finding {
                file: path.to_string(),
                line: p.line,
                rule: "pragma".into(),
                message: format!("malformed lint pragma ({})", p.reason),
            });
            continue;
        }
        if p.reason.is_empty() {
            pragma_findings.push(Finding {
                file: path.to_string(),
                line: p.line,
                rule: "pragma".into(),
                message: "lint:allow pragma without a justification suppresses nothing".into(),
            });
            continue;
        }
        let lines = if p.file_level {
            None
        } else if token_lines.contains(&p.line) {
            // trailing pragma: covers its own line
            Some((p.line, p.line))
        } else {
            // standalone comment: covers the next token-bearing line
            let next = token_lines.range(p.line + 1..).next().copied().unwrap_or(p.line);
            Some((next, next))
        };
        active.push(Active { p, lines, used: false });
    }

    let mut after: Vec<Finding> = Vec::new();
    let mut suppressed = 0usize;
    for f in raw {
        let mut hit = false;
        for a in active.iter_mut() {
            if !a.p.rules.iter().any(|r| r == &f.rule) {
                continue;
            }
            let covers = match a.lines {
                None => true,
                Some((lo, hi)) => f.line >= lo && f.line <= hi,
            };
            if covers {
                a.used = true;
                hit = true;
                break;
            }
        }
        if hit {
            suppressed += 1;
        } else {
            after.push(f);
        }
    }

    if cfg.strict_pragmas && only.is_none() {
        for a in &active {
            if !a.used {
                pragma_findings.push(Finding {
                    file: path.to_string(),
                    line: a.p.line,
                    rule: "pragma".into(),
                    message: format!(
                        "unused lint:allow({}) pragma — nothing to suppress here",
                        a.p.rules.join(",")
                    ),
                });
            }
        }
    }

    after.extend(pragma_findings);
    after.sort();
    FileFindings { after, raw: raw_count, suppressed }
}
