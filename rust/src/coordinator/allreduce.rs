//! Ring all-reduce across simulated devices (paper Fig. 8 "ALLReduce"
//! gradient synchronization for the data-parallel MLPs + TT cores).
//!
//! Real summation over worker threads (correctness-bearing) plus a
//! modeled link cost (2·(N−1)/N · bytes / bw) charged as wall time — the
//! same overlap semantics as the pipeline's transfers.
//!
//! Two exchanges share the deposit/merge protocol:
//!
//! * [`AllReduce::allreduce_weighted`] — the dense path: every worker
//!   ships its full parameter vector; the merge is a **shard-size
//!   weighted** mean (uniform weights compute the plain mean — the same
//!   ops as the old code at one worker, and at n > 1 one fixed instance
//!   of the arrival-order sums the old code produced
//!   nondeterministically), which is what makes uneven shards exact
//!   global-batch SGD.
//! * [`AllReduce::allreduce_sparse`] — the plan-placed path: workers ship
//!   only `(offset, delta)` runs covering the parameters their shard
//!   actually touched (TT-core slices of their owned prefix groups plus
//!   boundary rows shared across owners); the merge applies the weighted
//!   deltas onto the common pre-step base.  Returns the round's total
//!   payload bytes so callers can account the communication volume.
//!
//! Determinism: workers deposit into per-worker slots and every worker
//! merges the slots in worker-index order, so results are identical bits
//! on every worker and reproducible run to run regardless of arrival
//! order (the old shared-accumulator design summed in arrival order).

use std::sync::{Arc, Barrier, Mutex};

use crate::coordinator::platform::{CostModel, SimPlatform};

/// Sparse parameter delta: contiguous `(offset, len)` runs into a flat
/// region plus the concatenated per-element deltas.  This is the
/// `(offset, delta)` payload of [`AllReduce::allreduce_sparse`].
#[derive(Clone, Debug, Default)]
pub struct SparseDelta {
    /// `(start, len)` runs, ascending and non-overlapping.
    pub runs: Vec<(u32, u32)>,
    /// Deltas for every covered element, run by run.
    pub vals: Vec<f32>,
}

impl SparseDelta {
    pub fn clear(&mut self) {
        self.runs.clear();
        self.vals.clear();
    }

    /// Rebuild as `post - base`, keeping only elements that changed
    /// (adjacent changed elements merge into one run).  Buffers are
    /// reused across calls.
    pub fn diff(&mut self, base: &[f32], post: &[f32]) {
        assert_eq!(base.len(), post.len(), "sparse diff length mismatch");
        self.clear();
        let mut i = 0usize;
        while i < base.len() {
            if post[i] == base[i] {
                i += 1;
                continue;
            }
            let start = i;
            while i < base.len() && post[i] != base[i] {
                self.vals.push(post[i] - base[i]);
                i += 1;
            }
            self.runs.push((start as u32, (i - start) as u32));
        }
    }

    /// Wire size: 8 bytes per run header + 4 per delta element.
    pub fn payload_bytes(&self) -> u64 {
        (self.runs.len() * 8 + self.vals.len() * 4) as u64
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

/// int8-quantized sparse delta: the same `(offset, len)` runs as
/// [`SparseDelta`], with per-element deltas stored as int8 against one
/// symmetric scale **per run**, plus an error-feedback residual retained
/// on the sender so the quantization error re-enters the next round's
/// delta instead of being lost (keeps SGD convergence; see
/// [`SparseDeltaQ8::from_delta`]).
#[derive(Clone, Debug, Default)]
pub struct SparseDeltaQ8 {
    /// `(start, len)` runs, ascending and non-overlapping.
    pub runs: Vec<(u32, u32)>,
    /// Quantized deltas for every covered element, run by run.
    pub q: Vec<i8>,
    /// One symmetric scale per run (`q * scale` dequantizes).
    pub scales: Vec<f32>,
}

impl SparseDeltaQ8 {
    pub fn clear(&mut self) {
        self.runs.clear();
        self.q.clear();
        self.scales.clear();
    }

    /// Quantize `delta` with error feedback: each covered element ships
    /// `round((delta + residual) / scale)` and the sender's `residual`
    /// keeps what the int8 grid dropped, to be carried into the next
    /// round.  `residual` is indexed by the same flat region coordinates
    /// as the runs; untouched positions keep their residual until their
    /// parameter is next touched.  Buffers are reused across calls.
    pub fn from_delta(&mut self, delta: &SparseDelta, residual: &mut [f32]) {
        self.clear();
        let mut k = 0usize;
        for &(off, len) in delta.runs.iter() {
            let (off, len) = (off as usize, len as usize);
            assert!(off + len <= residual.len(), "residual region too small");
            // per-run symmetric scale over the error-compensated values
            let mut max = 0.0f32;
            for j in 0..len {
                max = max.max((delta.vals[k + j] + residual[off + j]).abs());
            }
            let scale = if max > 0.0 { max / 127.0 } else { 1.0 };
            for j in 0..len {
                let v = delta.vals[k + j] + residual[off + j];
                let q = (v / scale).round().clamp(-127.0, 127.0) as i8;
                residual[off + j] = v - q as f32 * scale;
                self.q.push(q);
            }
            self.runs.push((off as u32, len as u32));
            self.scales.push(scale);
            k += len;
        }
        debug_assert_eq!(k, delta.vals.len());
    }

    /// Wire size: 8 bytes per run header + 4 per run scale + 1 per
    /// quantized element.
    pub fn payload_bytes(&self) -> u64 {
        (self.runs.len() * 8 + self.scales.len() * 4 + self.q.len()) as u64
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

#[derive(Default)]
struct DenseSlot {
    weight: f32,
    buf: Vec<f32>,
}

#[derive(Default)]
struct SparseSlot {
    weight: f32,
    delta: SparseDelta,
    bytes: u64,
}

#[derive(Default)]
struct SparseQSlot {
    weight: f32,
    delta: SparseDeltaQ8,
    bytes: u64,
}

/// Shared all-reduce context for `n` workers.
pub struct AllReduce {
    n: usize,
    cost: CostModel,
    barrier: Barrier,
    dense: Vec<Mutex<DenseSlot>>,
    sparse: Vec<Mutex<SparseSlot>>,
    sparse_q: Vec<Mutex<SparseQSlot>>,
}

impl AllReduce {
    pub fn new(n: usize, len: usize, cost: CostModel) -> Arc<AllReduce> {
        Arc::new(AllReduce {
            n,
            cost,
            barrier: Barrier::new(n),
            dense: (0..n)
                .map(|_| Mutex::new(DenseSlot { weight: 0.0, buf: Vec::with_capacity(len) }))
                .collect(),
            sparse: (0..n).map(|_| Mutex::new(SparseSlot::default())).collect(),
            sparse_q: (0..n).map(|_| Mutex::new(SparseQSlot::default())).collect(),
        })
    }

    /// Reduce `data` across all workers (plain mean); every worker's slice
    /// is replaced by the mean.  Blocks until all `n` workers arrive.
    /// `w` is the caller's worker index.
    pub fn allreduce_mean(&self, w: usize, data: &mut [f32]) {
        self.allreduce_weighted(w, data, 1.0);
    }

    /// Weighted mean across all workers: every worker's slice is replaced
    /// by `Σ wᵢ·xᵢ / Σ wᵢ`.  With uniform weights of 1.0 the arithmetic
    /// is the old unweighted mean's (bit-identical at one worker; at
    /// n > 1 the fixed merge order is one deterministic instance of the
    /// arrival-order sums the old shared-accumulator produced, so runs
    /// are now reproducible rather than history-matching).  With weights
    /// proportional to shard sizes, averaging post-step parameters from a
    /// common starting point is exactly global-batch SGD even when
    /// `batch_size % n_workers != 0`.
    pub fn allreduce_weighted(&self, w: usize, data: &mut [f32], weight: f32) {
        // charge the ring cost once per worker (concurrent sleeps overlap,
        // so wall impact ≈ one ring time — matching a real ring)
        SimPlatform::charge(self.cost.allreduce_time((data.len() * 4) as u64, self.n));

        // deposit the pre-scaled contribution into this worker's slot
        {
            let mut slot = self.dense[w].lock().unwrap();
            slot.weight = weight;
            slot.buf.clear();
            slot.buf.extend_from_slice(data);
            if weight != 1.0 {
                for v in slot.buf.iter_mut() {
                    *v *= weight;
                }
            }
        }
        self.barrier.wait();
        // merge in worker-index order — identical bits on every worker
        let mut wsum = 0.0f32;
        data.fill(0.0);
        for ws in 0..self.n {
            let slot = self.dense[ws].lock().unwrap();
            assert_eq!(slot.buf.len(), data.len(), "allreduce length mismatch");
            wsum += slot.weight;
            for (d, &v) in data.iter_mut().zip(slot.buf.iter()) {
                *d += v;
            }
        }
        let inv = 1.0 / wsum;
        for d in data.iter_mut() {
            *d *= inv;
        }
        // nobody may re-deposit until every worker finished merging
        self.barrier.wait();
    }

    /// Sparse weighted exchange: every worker contributes the
    /// `(offset, delta)` runs its step produced over a shared flat
    /// `region` (the COMMON pre-step base), with its shard weight; on
    /// return every worker's `region` holds `base + Σ wᵢ·deltaᵢ / Σ wᵢ`
    /// — elementwise identical (in exact arithmetic) to the dense
    /// weighted mean of the post-step regions, at the wire cost of only
    /// the touched elements.  Workers with empty shards still call in
    /// (weight 0, empty delta) so their weight share is accounted and the
    /// barrier completes.  Returns the round's total payload bytes
    /// (identical on every worker).
    pub fn allreduce_sparse(
        &self,
        w: usize,
        region: &mut [f32],
        delta: &SparseDelta,
        weight: f32,
    ) -> u64 {
        let own_bytes = delta.payload_bytes();
        SimPlatform::charge(self.cost.allreduce_time(own_bytes, self.n));
        {
            let mut slot = self.sparse[w].lock().unwrap();
            slot.weight = weight;
            slot.bytes = own_bytes;
            slot.delta.runs.clear();
            slot.delta.runs.extend_from_slice(&delta.runs);
            slot.delta.vals.clear();
            slot.delta.vals.extend_from_slice(&delta.vals);
        }
        self.barrier.wait();
        // pass 1: total weight + payload (fixed order, identical everywhere)
        let mut wsum = 0.0f32;
        let mut total = 0u64;
        for ws in 0..self.n {
            let slot = self.sparse[ws].lock().unwrap();
            wsum += slot.weight;
            total += slot.bytes;
        }
        // pass 2: apply the weighted deltas onto the common base, in
        // worker-index order (overlapping offsets — boundary rows shared
        // across owners — accumulate deterministically)
        let inv = 1.0 / wsum;
        for ws in 0..self.n {
            let slot = self.sparse[ws].lock().unwrap();
            let scale = slot.weight * inv;
            let mut k = 0usize;
            for &(off, len) in slot.delta.runs.iter() {
                let off = off as usize;
                for j in 0..len as usize {
                    region[off + j] += slot.delta.vals[k] * scale;
                    k += 1;
                }
            }
        }
        self.barrier.wait();
        total
    }

    /// Quantized twin of [`allreduce_sparse`]: workers ship int8 runs
    /// with one f32 scale per run (≈4× fewer wire bytes than the f32
    /// deltas on run-dominated payloads).  The deposit/merge protocol —
    /// per-worker slots, barrier, two fixed-order passes, barrier — is
    /// identical, so the result is identical bits on every worker; the
    /// *values* differ from the f32 exchange only by the per-element
    /// quantization error, which the sender retains as error-feedback
    /// residual (see [`SparseDeltaQ8::from_delta`]) so it re-enters its
    /// next delta rather than compounding.  Returns the round's total
    /// payload bytes (identical on every worker).
    pub fn allreduce_sparse_q8(
        &self,
        w: usize,
        region: &mut [f32],
        delta: &SparseDeltaQ8,
        weight: f32,
    ) -> u64 {
        let own_bytes = delta.payload_bytes();
        SimPlatform::charge(self.cost.allreduce_time(own_bytes, self.n));
        {
            let mut slot = self.sparse_q[w].lock().unwrap();
            slot.weight = weight;
            slot.bytes = own_bytes;
            slot.delta.runs.clear();
            slot.delta.runs.extend_from_slice(&delta.runs);
            slot.delta.q.clear();
            slot.delta.q.extend_from_slice(&delta.q);
            slot.delta.scales.clear();
            slot.delta.scales.extend_from_slice(&delta.scales);
        }
        self.barrier.wait();
        // pass 1: total weight + payload (fixed order, identical everywhere)
        let mut wsum = 0.0f32;
        let mut total = 0u64;
        for ws in 0..self.n {
            let slot = self.sparse_q[ws].lock().unwrap();
            wsum += slot.weight;
            total += slot.bytes;
        }
        // pass 2: dequantize-and-apply onto the common base, in
        // worker-index order
        let inv = 1.0 / wsum;
        for ws in 0..self.n {
            let slot = self.sparse_q[ws].lock().unwrap();
            let wscale = slot.weight * inv;
            let mut k = 0usize;
            for (ri, &(off, len)) in slot.delta.runs.iter().enumerate() {
                let off = off as usize;
                let s = slot.delta.scales[ri] * wscale;
                for j in 0..len as usize {
                    region[off + j] += slot.delta.q[k] as f32 * s;
                    k += 1;
                }
            }
        }
        self.barrier.wait();
        total
    }
}

/// Error-feedback carry-over for straggler-excluded all-reduce rounds —
/// the dense-path mirror of [`SparseDeltaQ8`]'s residual mechanism.
///
/// When a worker misses a round's deadline it is excluded from that
/// round's weighted mean (weight 0), but its local step is not thrown
/// away: the caller [`absorb`](StragglerCarry::absorb)s `post − base`
/// into the carry, and at the start of the next round
/// [`fold_into`](StragglerCarry::fold_into) re-applies it onto the
/// consensus parameters before the worker computes its next step.  The
/// straggler's gradient information arrives one round late instead of
/// being dropped, which is what keeps convergence within tolerance of
/// full participation (pinned by `tests/fault_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct StragglerCarry {
    carry: Vec<f32>,
    nonzero: bool,
}

impl StragglerCarry {
    pub fn new(len: usize) -> StragglerCarry {
        StragglerCarry { carry: vec![0.0; len], nonzero: false }
    }

    /// Accumulate this round's unshipped local progress (`post − base`).
    pub fn absorb(&mut self, base: &[f32], post: &[f32]) {
        assert_eq!(base.len(), self.carry.len(), "carry length mismatch");
        assert_eq!(post.len(), self.carry.len(), "carry length mismatch");
        for ((c, &b), &p) in self.carry.iter_mut().zip(base).zip(post) {
            *c += p - b;
        }
        self.nonzero = true;
    }

    /// Re-apply the carried delta onto `params` and clear the carry.
    /// Returns whether anything was applied — false means `params` was
    /// not touched at all (no fold, no clear, zero arithmetic), so the
    /// straggler-free path stays bit-identical.
    pub fn fold_into(&mut self, params: &mut [f32]) -> bool {
        if !self.nonzero {
            return false;
        }
        assert_eq!(params.len(), self.carry.len(), "carry length mismatch");
        for (p, c) in params.iter_mut().zip(self.carry.iter_mut()) {
            *p += *c;
            *c = 0.0;
        }
        self.nonzero = false;
        true
    }

    pub fn is_empty(&self) -> bool {
        !self.nonzero
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cost() -> CostModel {
        CostModel {
            h2d_bps: 1e18,
            d2d_bps: 1e18,
            transfer_latency: Duration::ZERO,
            ps_row: Duration::ZERO,
            dispatch: Duration::ZERO,
        }
    }

    #[test]
    fn reduces_to_mean_across_workers() {
        let n = 4;
        let ar = AllReduce::new(n, 8, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut v = vec![(w + 1) as f32; 8];
                    ar.allreduce_mean(w, &mut v);
                    v
                })
            })
            .collect();
        for h in handles {
            let v = h.join().unwrap();
            for &x in &v {
                assert!((x - 2.5).abs() < 1e-6); // mean of 1..4
            }
        }
    }

    #[test]
    fn multiple_rounds_reset_correctly() {
        let n = 2;
        let ar = AllReduce::new(n, 2, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut out = Vec::new();
                    for round in 0..3 {
                        let mut v = vec![(w as f32) + round as f32; 2];
                        ar.allreduce_mean(w, &mut v);
                        out.push(v[0]);
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            let o = h.join().unwrap();
            assert_eq!(o, vec![0.5, 1.5, 2.5]);
        }
    }

    #[test]
    fn weighted_mean_weights_contributions() {
        // weights 3:1 — exact in f32, so the expectation is exact
        let ar = AllReduce::new(2, 1, cost());
        let handles: Vec<_> = (0..2)
            .map(|w| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let (val, weight) = if w == 0 { (8.0f32, 3.0) } else { (4.0, 1.0) };
                    let mut v = vec![val; 4];
                    ar.allreduce_weighted(w, &mut v, weight);
                    v
                })
            })
            .collect();
        for h in handles {
            // (3*8 + 1*4) / 4 = 7
            assert_eq!(h.join().unwrap(), vec![7.0; 4]);
        }
    }

    #[test]
    fn sparse_diff_finds_runs() {
        let base = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let post = vec![1.0f32, 2.5, 3.5, 4.0, 5.0, 7.0];
        let mut d = SparseDelta::default();
        d.diff(&base, &post);
        assert_eq!(d.runs, vec![(1, 2), (5, 1)]);
        assert_eq!(d.vals, vec![0.5, 0.5, 1.0]);
        assert_eq!(d.payload_bytes(), 2 * 8 + 3 * 4);
        d.diff(&base, &base);
        assert!(d.is_empty());
        assert_eq!(d.payload_bytes(), 0);
    }

    #[test]
    fn sparse_exchange_matches_dense_weighted_mean() {
        // two workers, disjoint + overlapping touched elements, weights
        // chosen exact in f32; sparse result must equal the dense
        // weighted mean of the post vectors
        let n = 2;
        let base = vec![10.0f32, 20.0, 30.0, 40.0];
        let posts = [vec![12.0f32, 20.0, 34.0, 40.0], vec![10.0f32, 24.0, 38.0, 40.0]];
        let weights = [1.0f32, 3.0];
        let ar = AllReduce::new(n, 4, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                let base = base.clone();
                let post = posts[w].clone();
                let weight = weights[w];
                std::thread::spawn(move || {
                    let mut delta = SparseDelta::default();
                    delta.diff(&base, &post);
                    let mut region = base.clone();
                    let bytes = ar.allreduce_sparse(w, &mut region, &delta, weight);
                    (region, bytes)
                })
            })
            .collect();
        // dense expectation: (1*post0 + 3*post1) / 4
        let want: Vec<f32> = (0..4)
            .map(|i| (posts[0][i] + 3.0 * posts[1][i]) / 4.0)
            .collect();
        let mut bytes_seen = Vec::new();
        for h in handles {
            let (region, bytes) = h.join().unwrap();
            assert_eq!(region, want);
            bytes_seen.push(bytes);
        }
        assert_eq!(bytes_seen[0], bytes_seen[1], "payload total must agree");
        assert!(bytes_seen[0] > 0);
    }

    #[test]
    fn q8_payload_strictly_below_f32_payload() {
        // one 16-element run: f32 = 8 + 64 bytes; q8 = 8 + 4 + 16 bytes
        let base = vec![0.0f32; 16];
        let post: Vec<f32> = (0..16).map(|i| (i + 1) as f32 * 0.01).collect();
        let mut d = SparseDelta::default();
        d.diff(&base, &post);
        let mut dq = SparseDeltaQ8::default();
        let mut residual = vec![0.0f32; 16];
        dq.from_delta(&d, &mut residual);
        assert_eq!(dq.runs, d.runs);
        assert_eq!(d.payload_bytes(), 8 + 64);
        assert_eq!(dq.payload_bytes(), 8 + 4 + 16);
        assert!(dq.payload_bytes() < d.payload_bytes());
    }

    #[test]
    fn q8_error_feedback_retains_what_the_grid_drops() {
        let base = vec![0.0f32; 4];
        let post = vec![1.0f32, 0.003, 0.5, 0.0];
        let mut d = SparseDelta::default();
        d.diff(&base, &post);
        let mut dq = SparseDeltaQ8::default();
        let mut residual = vec![0.0f32; 4];
        dq.from_delta(&d, &mut residual);
        // dequantized + residual reconstructs the exact delta
        let mut k = 0usize;
        for (ri, &(off, len)) in dq.runs.iter().enumerate() {
            for j in 0..len as usize {
                let deq = dq.q[k] as f32 * dq.scales[ri];
                let exact = post[off as usize + j] - base[off as usize + j];
                assert!(
                    (deq + residual[off as usize + j] - exact).abs() < 1e-6,
                    "elem {j}: {deq} + residual != {exact}"
                );
                k += 1;
            }
        }
        // the tiny element really was rounded — residual is nonzero there
        assert!(residual[1] != 0.0, "expected quantization error on 0.003");
        // a second round with zero new delta flushes the residual out
        let mut d2 = SparseDelta::default();
        d2.runs = d.runs.clone();
        d2.vals = vec![0.0; d.vals.len()];
        let before = residual.clone();
        let mut dq2 = SparseDeltaQ8::default();
        dq2.from_delta(&d2, &mut residual);
        let deq1 = dq2.q[1] as f32 * dq2.scales[0];
        assert!((deq1 + residual[1] - before[1]).abs() < 1e-6);
    }

    #[test]
    fn q8_all_zero_run_round_trips_zeros() {
        let mut d = SparseDelta::default();
        d.runs = vec![(2, 3)];
        d.vals = vec![0.0; 3];
        let mut dq = SparseDeltaQ8::default();
        let mut residual = vec![0.0f32; 8];
        dq.from_delta(&d, &mut residual);
        assert_eq!(dq.scales, vec![1.0]);
        assert_eq!(dq.q, vec![0, 0, 0]);
        assert!(residual.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn q8_exchange_close_to_f32_exchange() {
        // same deposit/merge protocol as the f32 sparse path; values may
        // differ only by the int8 grid (≤ max|v|/127 per element per
        // worker), and the totals must agree across workers
        let n = 2;
        let base = vec![10.0f32, 20.0, 30.0, 40.0];
        let posts = [vec![12.0f32, 20.0, 34.0, 40.0], vec![10.0f32, 24.0, 38.0, 40.0]];
        let weights = [1.0f32, 3.0];
        let ar = AllReduce::new(n, 4, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                let base = base.clone();
                let post = posts[w].clone();
                let weight = weights[w];
                std::thread::spawn(move || {
                    let mut delta = SparseDelta::default();
                    delta.diff(&base, &post);
                    let mut dq = SparseDeltaQ8::default();
                    let mut residual = vec![0.0f32; 4];
                    dq.from_delta(&delta, &mut residual);
                    let mut region = base.clone();
                    let bytes = ar.allreduce_sparse_q8(w, &mut region, &dq, weight);
                    (region, bytes, delta.payload_bytes())
                })
            })
            .collect();
        let want: Vec<f32> = (0..4)
            .map(|i| (posts[0][i] + 3.0 * posts[1][i]) / 4.0)
            .collect();
        let mut seen = Vec::new();
        for h in handles {
            let (region, bytes, f32_bytes) = h.join().unwrap();
            for (got, expect) in region.iter().zip(&want) {
                // deltas are ≤ 8 in magnitude -> grid step ≤ 8/127
                assert!((got - expect).abs() < 0.07, "{got} vs {expect}");
            }
            assert!(bytes < f32_bytes, "q8 {bytes} not below f32 {f32_bytes}");
            seen.push((region, bytes));
        }
        assert_eq!(seen[0], seen[1], "workers must agree bit-for-bit");
    }

    #[test]
    fn straggler_carry_round_trips_missed_progress() {
        let mut carry = StragglerCarry::new(3);
        assert!(carry.is_empty());

        // empty carry: fold_into must be a strict no-op (bit-identity)
        let mut params = vec![1.0f32, 2.0, 3.0];
        assert!(!carry.fold_into(&mut params));
        assert_eq!(params, vec![1.0, 2.0, 3.0]);

        // a missed round absorbs post − base…
        let base = vec![1.0f32, 2.0, 3.0];
        let post = vec![1.5f32, 2.0, 2.0];
        carry.absorb(&base, &post);
        assert!(!carry.is_empty());
        // …two missed rounds accumulate
        carry.absorb(&base, &post);

        // the fold re-applies the full accumulated delta, then clears
        let mut consensus = vec![10.0f32, 20.0, 30.0];
        assert!(carry.fold_into(&mut consensus));
        assert_eq!(consensus, vec![11.0, 20.0, 28.0]);
        assert!(carry.is_empty());
        assert!(!carry.fold_into(&mut consensus));
        assert_eq!(consensus, vec![11.0, 20.0, 28.0]);
    }

    #[test]
    fn empty_shard_participates_with_zero_weight() {
        let n = 3;
        let base = vec![5.0f32, 5.0];
        let ar = AllReduce::new(n, 2, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                let base = base.clone();
                std::thread::spawn(move || {
                    let mut delta = SparseDelta::default();
                    let weight = if w == 2 {
                        0.0 // empty shard: no delta, no weight share
                    } else {
                        let post = vec![5.0 + (w + 1) as f32, 5.0];
                        delta.diff(&base, &post);
                        1.5
                    };
                    let mut region = base.clone();
                    ar.allreduce_sparse(w, &mut region, &delta, weight);
                    region
                })
            })
            .collect();
        for h in handles {
            // (1.5*1 + 1.5*2) / 3.0 = 1.5 on element 0, untouched elsewhere
            assert_eq!(h.join().unwrap(), vec![6.5, 5.0]);
        }
    }
}
