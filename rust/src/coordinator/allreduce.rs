//! Ring all-reduce across simulated devices (paper Fig. 8 "ALLReduce"
//! gradient synchronization for the data-parallel MLPs + TT cores).
//!
//! Real summation over worker threads (correctness-bearing) plus a
//! modeled link cost (2·(N−1)/N · bytes / bw) charged as wall time — the
//! same overlap semantics as the pipeline's transfers.

use std::sync::{Arc, Barrier, Mutex};

use crate::coordinator::platform::{CostModel, SimPlatform};

/// Shared all-reduce context for `n` workers.
pub struct AllReduce {
    n: usize,
    acc: Mutex<Vec<f32>>,
    arrived: Mutex<usize>,
    barrier: Barrier,
    cost: CostModel,
}

impl AllReduce {
    pub fn new(n: usize, len: usize, cost: CostModel) -> Arc<AllReduce> {
        Arc::new(AllReduce {
            n,
            acc: Mutex::new(vec![0.0; len]),
            arrived: Mutex::new(0),
            barrier: Barrier::new(n),
            cost,
        })
    }

    /// Reduce `data` across all workers (mean); every worker's slice is
    /// replaced by the mean.  Blocks until all `n` workers arrive.
    pub fn allreduce_mean(&self, data: &mut [f32]) {
        // charge the ring cost once per worker (concurrent sleeps overlap,
        // so wall impact ≈ one ring time — matching a real ring)
        SimPlatform::charge(self.cost.allreduce_time((data.len() * 4) as u64, self.n));

        // accumulate
        {
            let mut acc = self.acc.lock().unwrap();
            assert_eq!(acc.len(), data.len(), "allreduce length mismatch");
            for (a, &d) in acc.iter_mut().zip(data.iter()) {
                *a += d;
            }
            let mut k = self.arrived.lock().unwrap();
            *k += 1;
        }
        self.barrier.wait();
        // read back the mean
        {
            let acc = self.acc.lock().unwrap();
            let inv = 1.0 / self.n as f32;
            for (d, &a) in data.iter_mut().zip(acc.iter()) {
                *d = a * inv;
            }
        }
        self.barrier.wait();
        // one worker resets for the next round
        {
            let mut k = self.arrived.lock().unwrap();
            if *k == self.n {
                *k = 0;
                let mut acc = self.acc.lock().unwrap();
                acc.fill(0.0);
            }
        }
        self.barrier.wait();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cost() -> CostModel {
        CostModel {
            h2d_bps: 1e18,
            d2d_bps: 1e18,
            transfer_latency: Duration::ZERO,
            ps_row: Duration::ZERO,
            dispatch: Duration::ZERO,
        }
    }

    #[test]
    fn reduces_to_mean_across_workers() {
        let n = 4;
        let ar = AllReduce::new(n, 8, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut v = vec![(w + 1) as f32; 8];
                    ar.allreduce_mean(&mut v);
                    v
                })
            })
            .collect();
        for h in handles {
            let v = h.join().unwrap();
            for &x in &v {
                assert!((x - 2.5).abs() < 1e-6); // mean of 1..4
            }
        }
    }

    #[test]
    fn multiple_rounds_reset_correctly() {
        let n = 2;
        let ar = AllReduce::new(n, 2, cost());
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar = ar.clone();
                std::thread::spawn(move || {
                    let mut out = Vec::new();
                    for round in 0..3 {
                        let mut v = vec![(w as f32) + round as f32; 2];
                        ar.allreduce_mean(&mut v);
                        out.push(v[0]);
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            let o = h.join().unwrap();
            assert_eq!(o, vec![0.5, 1.5, 2.5]);
        }
    }
}
