//! Bounded blocking queues for the pipeline trainer (paper Fig. 8): the
//! prefetch queue (PS → worker) and the gradient queue (worker → PS).
//!
//! The queue length is the paper's **LC (Load Capacity)** parameter: depth
//! 1 degrades the pipeline to sequential execution (the Fig. 14 ablation
//! arm), larger depths let the PS run ahead of the trainer.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// MPMC bounded blocking queue (mutex + condvars; contention here is two
/// threads, so a lock-free design buys nothing).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        assert!(cap >= 1);
        Arc::new(BoundedQueue {
            inner: Mutex::new(Inner { q: VecDeque::with_capacity(cap), closed: false }),
            cap,
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        })
    }

    /// Blocking push; returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return false;
        }
        g.q.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; returns `None` once closed AND drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Close: producers stop, consumers drain.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            assert!(q.push(i));
        }
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn blocks_at_capacity_until_pop() {
        let q = BoundedQueue::new(1);
        q.push(1);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(2));
        thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1); // producer blocked
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_unblocks_everyone() {
        let q: Arc<BoundedQueue<u32>> = BoundedQueue::new(1);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
        assert!(!q.push(9)); // push after close fails
    }

    #[test]
    fn producer_consumer_transfers_everything() {
        let q = BoundedQueue::new(3);
        let total = Arc::new(AtomicUsize::new(0));
        let qp = q.clone();
        let producer = thread::spawn(move || {
            for i in 1..=100usize {
                qp.push(i);
            }
            qp.close();
        });
        let tc = total.clone();
        let consumer = thread::spawn(move || {
            while let Some(x) = q.pop() {
                tc.fetch_add(x, Ordering::Relaxed);
            }
        });
        producer.join().unwrap();
        consumer.join().unwrap();
        assert_eq!(total.load(Ordering::Relaxed), 5050);
    }

    #[test]
    fn depth_one_serializes() {
        // LC=1: at most one item in flight — the sequential-mode premise
        let q = BoundedQueue::new(1);
        assert!(q.push(1));
        assert_eq!(q.len(), 1);
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.try_pop(), None);
    }
}

impl<T> BoundedQueue<T> {
    /// Arc helper so call sites read naturally.
    pub fn clone_arc(self: &Arc<Self>) -> Arc<Self> {
        Arc::clone(self)
    }
}
