//! `SimPlatform` — the device/interconnect cost model standing in for the
//! paper's AWS p3.8xlarge (4× V100) / g4dn.12xlarge (4× T4) testbeds
//! (DESIGN.md §4 substitution).
//!
//! Compute runs for real on CPU threads; **communication** (PCIe/NVLink
//! transfers, PS gathers, kernel dispatch) is charged from this model as
//! real sleeps, so pipeline overlap is genuinely concurrent rather than
//! analytically composed.  Because a CPU core is ~`cpu_slowdown`× slower
//! than the paper's GPUs at DLRM compute, link bandwidths are divided by
//! the same factor — preserving the compute:communication *ratio* the
//! paper's wins depend on, which is the quantity the benches reproduce.

use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Host↔device bandwidth, bytes/s (already slowdown-scaled).
    pub h2d_bps: f64,
    /// Device↔device bandwidth, bytes/s.
    pub d2d_bps: f64,
    /// Fixed per-transfer latency.
    pub transfer_latency: Duration,
    /// Host-side gather/update cost per embedding row.
    pub ps_row: Duration,
    /// Per-dispatch overhead (kernel launch / executable invoke).
    pub dispatch: Duration,
}

impl CostModel {
    /// Scale every cost by `f` (benches use this to shrink wall time
    /// without changing ratios).
    pub fn scaled(&self, f: f64) -> CostModel {
        CostModel {
            h2d_bps: self.h2d_bps / f,
            d2d_bps: self.d2d_bps / f,
            transfer_latency: mul(self.transfer_latency, f),
            ps_row: mul(self.ps_row, f),
            dispatch: mul(self.dispatch, f),
        }
    }

    pub fn h2d_time(&self, bytes: u64) -> Duration {
        self.transfer_latency + Duration::from_secs_f64(bytes as f64 / self.h2d_bps)
    }

    pub fn d2d_time(&self, bytes: u64) -> Duration {
        self.transfer_latency + Duration::from_secs_f64(bytes as f64 / self.d2d_bps)
    }

    pub fn gather_time(&self, rows: usize) -> Duration {
        mul(self.ps_row, rows as f64)
    }

    /// Ring all-reduce time for `bytes` over `n` devices:
    /// 2·(n−1)/n · bytes / link_bw.
    pub fn allreduce_time(&self, bytes: u64, n: usize) -> Duration {
        if n <= 1 {
            return Duration::ZERO;
        }
        let vol = 2.0 * (n as f64 - 1.0) / n as f64 * bytes as f64;
        self.transfer_latency * 2 + Duration::from_secs_f64(vol / self.d2d_bps)
    }

    /// All-to-all exchange (model-parallel embedding lookup).
    pub fn alltoall_time(&self, bytes: u64, n: usize) -> Duration {
        if n <= 1 {
            return Duration::ZERO;
        }
        let vol = bytes as f64 * (n as f64 - 1.0) / n as f64;
        self.transfer_latency + Duration::from_secs_f64(vol / self.d2d_bps)
    }
}

fn mul(d: Duration, f: f64) -> Duration {
    Duration::from_secs_f64(d.as_secs_f64() * f)
}

/// Scale a *time* cost up by the CPU slowdown.
///
/// Compute runs on a CPU that is `slow`× slower than the paper's GPU, so
/// every modeled latency/overhead must stretch by the same factor or the
/// compute:communication balance (the quantity every pipeline/PS result
/// depends on) would be silently distorted.  Bandwidth-derived costs get
/// the same treatment by dividing the bandwidths above.
fn scale_t(d: Duration, slow: f64) -> Duration {
    mul(d, slow)
}

/// Platform presets.
#[derive(Clone, Copy, Debug)]
pub struct SimPlatform {
    pub name: &'static str,
    pub n_devices: usize,
    /// Per-device memory capacity (the spill threshold that forces PS
    /// mode for uncompressed tables — Fig. 13's premise).
    pub hbm_bytes: u64,
    pub cost: CostModel,
    /// How much slower one CPU core is vs. this GPU at DLRM compute
    /// (documentation of the scaling baked into `cost`).
    pub cpu_slowdown: f64,
}

impl SimPlatform {
    /// AWS p3.8xlarge: V100 16 GB, PCIe gen3 ~12 GB/s, NVLink ~100 GB/s.
    pub fn v100(n_devices: usize) -> SimPlatform {
        let slow = 100.0;
        SimPlatform {
            name: "V100",
            n_devices,
            hbm_bytes: 16 << 30,
            cost: CostModel {
                h2d_bps: 12e9 / slow,
                d2d_bps: 100e9 / slow,
                transfer_latency: scale_t(Duration::from_micros(10), slow),
                ps_row: scale_t(Duration::from_nanos(120), slow),
                dispatch: scale_t(Duration::from_micros(8), slow),
            },
            cpu_slowdown: slow,
        }
    }

    /// AWS g4dn.12xlarge: T4 15 GB, PCIe ~12 GB/s, no NVLink (PCIe P2P).
    pub fn t4(n_devices: usize) -> SimPlatform {
        let slow = 40.0; // T4 is ~2.5x slower than V100 at this workload
        SimPlatform {
            name: "T4",
            n_devices,
            hbm_bytes: 15 << 30,
            cost: CostModel {
                h2d_bps: 12e9 / slow,
                d2d_bps: 12e9 / slow,
                transfer_latency: scale_t(Duration::from_micros(10), slow),
                ps_row: scale_t(Duration::from_nanos(120), slow),
                dispatch: scale_t(Duration::from_micros(8), slow),
            },
            cpu_slowdown: slow,
        }
    }

    /// RTX 2060 edge box (Table VI's deployment platform).
    pub fn rtx2060() -> SimPlatform {
        let slow = 30.0;
        SimPlatform {
            name: "RTX2060",
            n_devices: 1,
            hbm_bytes: 6 << 30,
            cost: CostModel {
                h2d_bps: 12e9 / slow,
                d2d_bps: 12e9 / slow,
                transfer_latency: scale_t(Duration::from_micros(12), slow),
                ps_row: scale_t(Duration::from_nanos(150), slow),
                dispatch: scale_t(Duration::from_micros(10), slow),
            },
            cpu_slowdown: slow,
        }
    }

    /// Charge a cost as real wall time (the pipeline threads genuinely
    /// overlap these sleeps with compute).
    pub fn charge(d: Duration) {
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }

    /// Does a table set of `bytes` fit in HBM next to activations?
    /// (90% usable heuristic.)
    pub fn fits_hbm(&self, bytes: u64) -> bool {
        (bytes as f64) < self.hbm_bytes as f64 * 0.9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let p = SimPlatform::v100(1);
        let t1 = p.cost.h2d_time(1 << 20);
        let t2 = p.cost.h2d_time(1 << 24);
        assert!(t2 > t1);
        assert!(t2.as_secs_f64() > 10.0 * (t1.as_secs_f64() - 2e-5));
    }

    #[test]
    fn allreduce_zero_for_single_device() {
        let p = SimPlatform::v100(1);
        assert_eq!(p.cost.allreduce_time(1 << 20, 1), Duration::ZERO);
        assert!(p.cost.allreduce_time(1 << 20, 4) > Duration::ZERO);
    }

    #[test]
    fn v100_nvlink_faster_than_t4_pcie() {
        let v = SimPlatform::v100(4);
        let t = SimPlatform::t4(4);
        // same logical volume: V100's (scaled) NVLink must beat T4's PCIe
        // by less than the raw 8x because T4's slowdown scale is smaller
        let tv = v.cost.d2d_time(100 << 20).as_secs_f64();
        let tt = t.cost.d2d_time(100 << 20).as_secs_f64();
        assert!(tv < tt);
    }

    #[test]
    fn hbm_capacity_gate() {
        let p = SimPlatform::v100(1);
        assert!(p.fits_hbm(1 << 30));
        assert!(!p.fits_hbm(19 << 30)); // Fig. 13's 19 GB table
    }

    #[test]
    fn scaled_preserves_ratio() {
        let c = SimPlatform::v100(1).cost;
        let s = c.scaled(10.0);
        let r0 = c.h2d_time(1 << 26).as_secs_f64() / c.d2d_time(1 << 26).as_secs_f64();
        let r1 = s.h2d_time(1 << 26).as_secs_f64() / s.d2d_time(1 << 26).as_secs_f64();
        assert!((r0 - r1).abs() < 0.2 * r0);
    }
}
