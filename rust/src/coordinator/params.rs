//! Host-memory parameter server state (paper Fig. 8, "CPU as parameter
//! server"): the authoritative copy of every embedding table that does not
//! fit (or is not placed) in device memory.
//!
//! Workers ship back *updated row values* (value shipping — equivalent to
//! grads under single-writer SGD and cheaper to reconcile); `applied`
//! counts steps whose updates have landed, and doubles as the snapshot
//! version carried by prefetched rows for the RAW protocol.

use std::collections::{BTreeMap, HashMap};

use crate::coordinator::cache::{PrefetchBatch, PrefetchedRow};
use crate::data::ctr::Batch;
use crate::tt::plain::PlainTable;
use crate::util::prng::Rng;

/// Updated rows for one step (worker → PS).
pub struct GradPacket {
    pub step: u64,
    /// (host-table slot, row, new row values)
    pub rows: Vec<(usize, u64, Vec<f32>)>,
}

impl GradPacket {
    pub fn bytes(&self) -> u64 {
        self.rows
            .iter()
            .map(|(_, _, v)| (v.len() * 4 + 16) as u64)
            .sum()
    }
}

/// Host-resident tables, addressed by *slot* (position in the engine's
/// table list).
pub struct HostParams {
    /// slot id -> table.  A BTreeMap, not a hash map: `snapshot_for`
    /// iterates it, and the prefetch rows must come out in slot order on
    /// every run (lint rule D1).
    pub tables: BTreeMap<usize, PlainTable>,
    /// Steps whose updates have been applied (the host version).
    pub applied: u64,
}

impl HostParams {
    /// Take ownership of the given engine slots' tables.
    pub fn new(slots: Vec<(usize, u64, usize)>, rng: &mut Rng) -> HostParams {
        let tables = slots
            .into_iter()
            .map(|(slot, rows, dim)| (slot, PlainTable::new(rows, dim, rng)))
            .collect();
        HostParams { tables, applied: 0 }
    }

    pub fn bytes(&self) -> u64 {
        self.tables.values().map(|t| t.bytes()).sum()
    }

    /// Snapshot the rows a batch will need from host tables, stamped with
    /// the current host version (paper: "inject host memory values into
    /// the prefetch queues").
    pub fn snapshot_for(&self, batch: &Batch, n_sparse: usize, step: u64) -> PrefetchBatch {
        let mut rows = Vec::new();
        let mut seen: HashMap<(usize, u64), ()> = HashMap::new();
        for (&slot, table) in self.tables.iter() {
            for idx in batch.sparse_col(slot, n_sparse) {
                if seen.insert((slot, idx), ()).is_none() {
                    rows.push((
                        slot,
                        PrefetchedRow {
                            row: idx,
                            data: table.row(idx).to_vec(),
                            version: self.applied,
                        },
                    ));
                }
            }
        }
        PrefetchBatch { step, rows }
    }

    /// Apply a worker's updated rows (value shipping).
    pub fn apply(&mut self, packet: &GradPacket) {
        for (slot, row, values) in &packet.rows {
            if let Some(t) = self.tables.get_mut(slot) {
                t.row_mut(*row).copy_from_slice(values);
            }
        }
        self.applied += 1;
    }

    /// Number of distinct host rows a batch touches (transfer accounting).
    pub fn rows_needed(&self, batch: &Batch, n_sparse: usize) -> usize {
        let mut seen = std::collections::HashSet::new();
        for (&slot, _) in self.tables.iter() {
            for idx in batch.sparse_col(slot, n_sparse) {
                seen.insert((slot, idx));
            }
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch2(slots: usize, b: usize) -> Batch {
        Batch {
            dense: vec![0.0; b],
            sparse: (0..b * slots).map(|i| (i % 5) as u64).collect(),
            labels: vec![0.0; b],
            batch_size: b,
        }
    }

    #[test]
    fn snapshot_dedups_and_versions() {
        let mut rng = Rng::new(1);
        let hp = HostParams::new(vec![(0, 10, 4), (1, 10, 4)], &mut rng);
        let b = batch2(2, 6);
        let snap = hp.snapshot_for(&b, 2, 0);
        // 6 samples × 2 tables but only 5 distinct ids per table
        assert!(snap.rows.len() <= 10);
        for (_, r) in &snap.rows {
            assert_eq!(r.version, 0);
        }
    }

    #[test]
    fn apply_bumps_version_and_writes_values() {
        let mut rng = Rng::new(2);
        let mut hp = HostParams::new(vec![(0, 10, 4)], &mut rng);
        let packet = GradPacket {
            step: 0,
            rows: vec![(0, 3, vec![7.0; 4])],
        };
        hp.apply(&packet);
        assert_eq!(hp.applied, 1);
        assert_eq!(hp.tables[&0].row(3), &[7.0; 4]);
    }

    #[test]
    fn rows_needed_counts_distinct() {
        let mut rng = Rng::new(3);
        let hp = HostParams::new(vec![(1, 10, 4)], &mut rng);
        let b = batch2(2, 8);
        // table slot 1 sees ids {1,3} pattern: i%5 over odd positions
        let n = hp.rows_needed(&b, 2);
        assert!(n >= 1 && n <= 5);
    }
}
