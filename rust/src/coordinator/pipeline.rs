//! Three-stage pipeline trainer (paper §IV, Figs. 8/9/14).
//!
//! Stage layout per step i (pipelined mode, two OS threads):
//!
//! ```text
//!   PS thread      : apply grads of i−1 │ snapshot+gather batch i+1 │ …
//!   worker thread  :   RAW-sync i │ fwd/bwd i (real compute) │ ship rows
//! ```
//!
//! Device-resident tables (Eff-TT compressed) never cross the link; host-
//! resident tables flow through the prefetch/gradient queues with the
//! Fig. 9(b) cache patching stale rows.  Because the worker's own updates
//! are what the cache holds, a patched row always equals the value a fully
//! sequential run would have used — pipeline and sequential training are
//! **bit-identical** (asserted in tests), the pipeline is pure overlap.
//!
//! Sequential mode (`pipelined=false`) is the Fig. 14 "prefetch queue
//! length 1" arm: the same operations on one thread, nothing overlaps.

use std::time::{Duration, Instant};

use crate::access::{AccessPlanner, BatchPlan};
use crate::coordinator::cache::EmbeddingCache;
use crate::coordinator::engine::{NativeDlrm, TableSlot};
use crate::coordinator::params::{GradPacket, HostParams};
use crate::coordinator::platform::{CostModel, SimPlatform};
use crate::coordinator::queues::BoundedQueue;
use crate::data::ctr::Batch;
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct PipelineCfg {
    /// Prefetch queue depth — the paper's LC parameter.
    pub lc: usize,
    /// false ⇒ the Fig. 14 sequential arm.
    pub pipelined: bool,
    /// Cache lifecycle init.
    pub cache_lc: u32,
    pub cost: CostModel,
    /// Engine table slots whose parameters live in host memory.
    pub host_slots: Vec<usize>,
    /// Disable the RAW synchronizer (correctness ablation: stale reads).
    pub disable_raw_sync: bool,
    /// Access planner the PS/ingest stage plans batches with — profiled
    /// and/or online-reordering planners slot in here (host-slot columns
    /// stay raw: `AccessPlanner` only ever remaps compressed slots, which
    /// is exactly what the prefetch/gradient row keys rely on).  `None`
    /// falls back to the identity planner for the engine config.
    pub planner: Option<AccessPlanner>,
}

impl PipelineCfg {
    pub fn new(cost: CostModel, host_slots: Vec<usize>) -> PipelineCfg {
        PipelineCfg {
            lc: 4,
            pipelined: true,
            cache_lc: 8,
            cost,
            host_slots,
            disable_raw_sync: false,
            planner: None,
        }
    }
}

#[derive(Debug)]
pub struct PipelineReport {
    pub steps: u64,
    pub samples: u64,
    pub wall: Duration,
    pub throughput: f64,
    pub losses: Vec<f32>,
    pub raw_fixed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub host_bytes_moved: u64,
}

/// Move the configured slots' tables out of the engine into a PS store.
/// The engine keeps same-shaped mirrors (refreshed per prefetch).
pub fn split_to_host(engine: &mut NativeDlrm, host_slots: &[usize], rng: &mut Rng) -> HostParams {
    let dim = engine.cfg.emb_dim;
    let mut slots = Vec::new();
    for &s in host_slots {
        match &engine.tables[s] {
            TableSlot::Plain(t) => slots.push((s, t.rows, dim)),
            TableSlot::Tt(_) => panic!("host slots must be plain tables (slot {s})"),
        }
    }
    let mut hp = HostParams::new(slots, rng);
    // engine mirrors start identical to the authoritative host copy
    for (&slot, table) in hp.tables.iter_mut() {
        if let TableSlot::Plain(mirror) = &mut engine.tables[slot] {
            mirror.weights.copy_from_slice(&table.weights);
        }
    }
    hp
}

/// Run training over `batches`; returns the report, the trained engine,
/// and the final host params (post-drain, consistent with the engine).
pub fn run(
    mut engine: NativeDlrm,
    mut host: HostParams,
    batches: &[Batch],
    cfg: &PipelineCfg,
) -> (PipelineReport, NativeDlrm, HostParams) {
    if cfg.pipelined {
        run_pipelined(engine, host, batches, cfg)
    } else {
        // -------- sequential arm: one thread, no overlap ----------------
        let n_sparse = engine.cfg.n_tables();
        let dim = engine.cfg.emb_dim;
        let mut planner = cfg
            .planner
            .clone()
            .unwrap_or_else(|| AccessPlanner::for_engine_cfg(&engine.cfg));
        let mut plan = BatchPlan::default();
        let mut cache = EmbeddingCache::new(cfg.cache_lc);
        let mut losses = Vec::with_capacity(batches.len());
        let mut moved = 0u64;
        // lint:allow(D2) measured wall time of the real run IS the bench metric
        let t0 = Instant::now();
        for (step, batch) in batches.iter().enumerate() {
            let mut pf = host.snapshot_for(batch, n_sparse, step as u64);
            let bytes = (pf.rows.len() * dim * 4) as u64;
            SimPlatform::charge(cfg.cost.gather_time(pf.rows.len()) + cfg.cost.h2d_time(bytes));
            moved += bytes;
            cache.sync_prefetch(&mut pf); // no conflicts possible here
            install_rows(&mut engine, &pf.rows);
            planner.plan_into(batch, &mut plan);
            losses.push(engine.train_step_planned(batch, &plan));
            let packet = collect_updates(&engine, batch, &cfg.host_slots, n_sparse, step as u64);
            let pbytes = packet.bytes();
            SimPlatform::charge(cfg.cost.h2d_time(pbytes)); // D2H, same link
            moved += pbytes;
            for (slot, row, vals) in &packet.rows {
                cache.record_update(*slot, *row, vals, step as u64 + 1);
            }
            SimPlatform::charge(cfg.cost.gather_time(packet.rows.len()));
            host.apply(&packet);
            cache.end_step();
        }
        let wall = t0.elapsed();
        let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
        let report = PipelineReport {
            steps: batches.len() as u64,
            samples,
            wall,
            throughput: samples as f64 / wall.as_secs_f64(),
            losses,
            raw_fixed: cache.raw_conflicts_fixed,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_evictions: cache.evictions,
            host_bytes_moved: moved,
        };
        (report, engine, host)
    }
}

fn run_pipelined(
    mut engine: NativeDlrm,
    mut host: HostParams,
    batches: &[Batch],
    cfg: &PipelineCfg,
) -> (PipelineReport, NativeDlrm, HostParams) {
    let n_sparse = engine.cfg.n_tables();
    let dim = engine.cfg.emb_dim;
    let n = batches.len();
    // The PS thread is also the ingest stage: it plans batch access
    // (column extraction + TT dedup) alongside the parameter snapshot,
    // overlapping both with the worker's compute.  Plans (and online
    // bijection refreshes, whose adoption points are functions of the
    // batch index alone) are deterministic in the batch sequence, so
    // pipeline == sequential still holds for any configured planner.
    let mut planner = cfg
        .planner
        .clone()
        .unwrap_or_else(|| AccessPlanner::for_engine_cfg(&engine.cfg));
    let prefetch_q: std::sync::Arc<BoundedQueue<(crate::coordinator::cache::PrefetchBatch, BatchPlan)>> =
        BoundedQueue::new(cfg.lc.max(1));
    // spent plan shells flow back worker → PS so the steady state reuses
    // ~lc plan buffers instead of allocating one per step
    let (plan_recycle_tx, plan_recycle_rx) = std::sync::mpsc::channel::<BatchPlan>();
    // grad queue effectively unbounded to keep the two blocking pushes
    // deadlock-free (PS only drains between prefetches)
    let grad_q: std::sync::Arc<BoundedQueue<GradPacket>> = BoundedQueue::new(n + 1);

    // lint:allow(D2) measured wall time of the real run IS the bench metric
    let t0 = Instant::now();
    let (report, eng, hp) = std::thread::scope(|scope| {
        // ---------------- PS thread (CPU side of Fig. 8) ----------------
        let ps_pf = prefetch_q.clone_arc();
        let ps_gq = grad_q.clone_arc();
        let ps_cost = cfg.cost;
        let ps_batches = batches;
        let ps_planner = &mut planner;
        let ps_handle = scope.spawn(move || {
            let mut moved = 0u64;
            for (step, batch) in ps_batches.iter().enumerate() {
                // land any finished gradients first (keeps staleness at
                // the minimum the queue depth forces)
                while let Some(p) = ps_gq.try_pop() {
                    SimPlatform::charge(ps_cost.gather_time(p.rows.len()));
                    host.apply(&p);
                }
                let pf = host.snapshot_for(batch, n_sparse, step as u64);
                let bytes = (pf.rows.len() * dim * 4) as u64;
                SimPlatform::charge(ps_cost.gather_time(pf.rows.len()) + ps_cost.h2d_time(bytes));
                moved += bytes;
                let mut plan = plan_recycle_rx.try_recv().unwrap_or_default();
                ps_planner.plan_into(batch, &mut plan);
                if !ps_pf.push((pf, plan)) {
                    break;
                }
            }
            ps_pf.close();
            // drain the tail
            while let Some(p) = ps_gq.pop() {
                SimPlatform::charge(ps_cost.gather_time(p.rows.len()));
                host.apply(&p);
            }
            (host, moved)
        });

        // ---------------- worker thread (device side) -------------------
        let wk_pf = prefetch_q.clone_arc();
        let wk_gq = grad_q.clone_arc();
        let wk_cost = cfg.cost;
        let host_slots = cfg.host_slots.clone();
        let disable_sync = cfg.disable_raw_sync;
        let cache_lc = cfg.cache_lc;
        let wk_handle = scope.spawn(move || {
            let mut cache = EmbeddingCache::new(cache_lc);
            let mut losses = Vec::with_capacity(n);
            let mut moved = 0u64;
            for (step, batch) in batches.iter().enumerate() {
                let (mut pf, plan) = match wk_pf.pop() {
                    Some(p) => p,
                    None => break,
                };
                if !disable_sync {
                    cache.sync_prefetch(&mut pf);
                }
                install_rows(&mut engine, &pf.rows);
                losses.push(engine.train_step_planned(batch, &plan));
                let packet =
                    collect_updates(&engine, batch, &host_slots, n_sparse, step as u64);
                for (slot, row, vals) in &packet.rows {
                    cache.record_update(*slot, *row, vals, step as u64 + 1);
                }
                let pbytes = packet.bytes();
                SimPlatform::charge(wk_cost.h2d_time(pbytes));
                moved += pbytes;
                wk_gq.push(packet);
                cache.end_step();
                let _ = plan_recycle_tx.send(plan);
            }
            wk_gq.close();
            (engine, cache, losses, moved)
        });

        let (host, ps_moved) = ps_handle.join().unwrap();
        let (engine, cache, losses, wk_moved) = wk_handle.join().unwrap();
        let wall = t0.elapsed();
        let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
        let report = PipelineReport {
            steps: losses.len() as u64,
            samples,
            wall,
            throughput: samples as f64 / wall.as_secs_f64(),
            losses,
            raw_fixed: cache.raw_conflicts_fixed,
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_evictions: cache.evictions,
            host_bytes_moved: ps_moved + wk_moved,
        };
        (report, engine, host)
    });
    (report, eng, hp)
}

/// Write prefetched host rows into the engine's device mirrors.
fn install_rows(engine: &mut NativeDlrm, rows: &[(usize, crate::coordinator::cache::PrefetchedRow)]) {
    for (slot, pr) in rows {
        if let TableSlot::Plain(mirror) = &mut engine.tables[*slot] {
            mirror.row_mut(pr.row).copy_from_slice(&pr.data);
        }
    }
}

/// Read back the batch's touched host-table rows after the local update.
fn collect_updates(
    engine: &NativeDlrm,
    batch: &Batch,
    host_slots: &[usize],
    n_sparse: usize,
    step: u64,
) -> GradPacket {
    let mut rows = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for &slot in host_slots {
        if let TableSlot::Plain(mirror) = &engine.tables[slot] {
            for idx in batch.sparse_col(slot, n_sparse) {
                if seen.insert((slot, idx)) {
                    rows.push((slot, idx, mirror.row(idx).to_vec()));
                }
            }
        }
    }
    GradPacket { step, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::data::schema::DatasetSchema;
    use crate::data::ctr::CtrGenerator;
    use crate::tt::table::EffTtOptions;

    fn cfg_and_batches() -> (EngineCfg, Vec<Batch>) {
        let ecfg = EngineCfg {
            dense_dim: 4,
            emb_dim: 8,
            tables: vec![(2000, true), (400, false), (300, false)],
            tt_rank: 4,
            bot_hidden: vec![16],
            top_hidden: vec![16],
            lr: 0.05,
            tt_opts: EffTtOptions::default(),
            exec: crate::exec::ExecCfg::default(),
        };
        let schema = DatasetSchema {
            name: "pipe-test",
            n_dense: 4,
            vocabs: vec![2000, 400, 300],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 7);
        let batches = gen.batches(30, 16);
        (ecfg, batches)
    }

    fn zero_cost() -> CostModel {
        CostModel {
            h2d_bps: 1e18,
            d2d_bps: 1e18,
            transfer_latency: Duration::ZERO,
            ps_row: Duration::ZERO,
            dispatch: Duration::ZERO,
        }
    }

    #[test]
    fn pipeline_matches_sequential_bitwise() {
        // The RAW synchronizer's whole job: pipelined training must
        // produce the SAME loss trajectory as sequential.
        let (ecfg, batches) = cfg_and_batches();
        let run_mode = |pipelined: bool| -> Vec<f32> {
            let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(11));
            let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(22));
            let mut pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
            pcfg.pipelined = pipelined;
            pcfg.lc = 4;
            let (report, _, _) = run(engine, host, &batches, &pcfg);
            report.losses
        };
        let seq = run_mode(false);
        let pipe = run_mode(true);
        assert_eq!(seq.len(), pipe.len());
        for (i, (a, b)) in seq.iter().zip(&pipe).enumerate() {
            assert_eq!(a, b, "divergence at step {i}: {a} vs {b}");
        }
    }

    /// A profiled (remapping) planner threaded through `PipelineCfg` must
    /// keep the pipeline == sequential guarantee AND leave host-slot rows
    /// raw (the planner only remaps compressed slots, so the prefetch /
    /// gradient row keys still address the host tables correctly).
    #[test]
    fn pipeline_with_profiled_planner_matches_sequential_bitwise() {
        let (ecfg, batches) = cfg_and_batches();
        let profile = &batches[..8];
        let planner = AccessPlanner::with_profile(&ecfg, profile, 0.1);
        assert!(planner.bijection(0).is_some(), "TT slot must be remapped");
        assert!(planner.bijection(1).is_none(), "host slots must stay raw");
        let run_mode = |pipelined: bool| -> Vec<f32> {
            let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(31));
            let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(32));
            let mut pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
            pcfg.pipelined = pipelined;
            pcfg.lc = 4;
            pcfg.planner = Some(planner.clone());
            let (report, _, _) = run(engine, host, &batches, &pcfg);
            report.losses
        };
        let seq = run_mode(false);
        let pipe = run_mode(true);
        assert_eq!(seq.len(), pipe.len());
        for (i, (a, b)) in seq.iter().zip(&pipe).enumerate() {
            assert_eq!(a, b, "divergence at step {i}: {a} vs {b}");
        }
        // and the remap must actually change training vs identity
        let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(31));
        let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(32));
        let mut pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
        pcfg.pipelined = false;
        let (ident, _, _) = run(engine, host, &batches, &pcfg);
        assert_ne!(ident.losses, seq, "profiled remap had no effect");
    }

    #[test]
    fn raw_conflicts_happen_and_get_fixed() {
        let (ecfg, batches) = cfg_and_batches();
        let mut engine = NativeDlrm::new(ecfg, &mut Rng::new(11));
        let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(22));
        let mut pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
        pcfg.lc = 6; // deep queue => lots of run-ahead => staleness
        let (report, _, _) = run(engine, host, &batches, &pcfg);
        assert!(
            report.raw_fixed > 0,
            "zipf-skewed stream with deep prefetch must hit RAW conflicts"
        );
    }

    #[test]
    fn disabling_sync_diverges() {
        // Negative control: without the Fig. 9(b) synchronizer the loss
        // trajectory must differ from sequential (stale reads).
        let (ecfg, batches) = cfg_and_batches();
        let mk = |sync_off: bool| -> Vec<f32> {
            let mut engine = NativeDlrm::new(ecfg.clone(), &mut Rng::new(11));
            let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(22));
            let mut pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
            pcfg.lc = 6;
            pcfg.disable_raw_sync = sync_off;
            let (r, _, _) = run(engine, host, &batches, &pcfg);
            r.losses
        };
        let with_sync = mk(false);
        let without = mk(true);
        assert_ne!(with_sync, without, "stale reads should perturb training");
    }

    #[test]
    fn host_and_device_converge_after_drain() {
        let (ecfg, batches) = cfg_and_batches();
        let mut engine = NativeDlrm::new(ecfg, &mut Rng::new(1));
        let host = split_to_host(&mut engine, &[1], &mut Rng::new(2));
        let pcfg = PipelineCfg::new(zero_cost(), vec![1]);
        let (_, engine, host) = run(engine, host, &batches, &pcfg);
        // every host row the stream touched must equal the device mirror
        if let TableSlot::Plain(mirror) = &engine.tables[1] {
            let auth = &host.tables[&1];
            for r in 0..auth.rows {
                let (a, b) = (auth.row(r), mirror.row(r));
                for (x, y) in a.iter().zip(b) {
                    assert!((x - y).abs() < 1e-6, "row {r} host/device drift");
                }
            }
        } else {
            panic!("slot 1 must be plain");
        }
    }

    #[test]
    fn training_actually_learns_through_pipeline() {
        let (ecfg, batches) = cfg_and_batches();
        let mut engine = NativeDlrm::new(ecfg, &mut Rng::new(5));
        let host = split_to_host(&mut engine, &[1, 2], &mut Rng::new(6));
        let pcfg = PipelineCfg::new(zero_cost(), vec![1, 2]);
        let (report, _, _) = run(engine, host, &batches, &pcfg);
        let head: f32 = report.losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = report.losses[report.losses.len() - 5..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "loss not trending down: {head} -> {tail}");
    }
}
