//! Native DLRM compute engine — a rust mirror of the L2 jax model.
//!
//! The PJRT runtime executes the AOT artifacts at fixed artifact shapes;
//! this engine runs the *same architecture* natively so the system benches
//! (batch 4096, multi-million-row tables, multi-worker — Figs. 10–14) pay
//! zero per-batch dispatch overhead and can scale shapes freely.  Both
//! paths are cross-checked in the integration tests.
//!
//! Architecture (paper Fig. 2): bottom MLP → [Eff-TT | plain] embedding
//! lookups → pairwise-dot interaction → top MLP → BCE.

use crate::access::plan::{BagLayout, BatchPlan};
use crate::data::ctr::Batch;
use crate::exec::par::{par_gemm_at_overwrite, par_gemm_bt_acc, par_row_blocks};
use crate::exec::{ExecCfg, ExecPool};
use crate::tt::linalg::{axpy, gemm_acc, gemm_bt_acc};
use crate::tt::plain::PlainTable;
use crate::tt::shapes::TtShapes;
use crate::tt::table::{EffTtOptions, EffTtTable, QuantizeMode, TtScratch};
use crate::util::prng::Rng;

/// One dense layer (row-major weights [din, dout]).
#[derive(Clone)]
pub struct DenseLayer {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl DenseLayer {
    fn new(din: usize, dout: usize, rng: &mut Rng) -> DenseLayer {
        let mut w = vec![0.0; din * dout];
        let std = (2.0 / din as f64).sqrt() as f32;
        rng.fill_normal(&mut w, 0.0, std);
        DenseLayer { din, dout, w, b: vec![0.0; dout] }
    }

    /// out[b, dout] = x[b, din] · W + b.  Batch rows sharded over the
    /// exec pool; bit-identical to serial for any worker count.
    fn forward(&self, pool: &ExecPool, x: &[f32], out: &mut [f32], bsz: usize) {
        let (din, dout) = (self.din, self.dout);
        out.fill(0.0);
        if pool.is_serial() || bsz < 2 || bsz * din * dout < crate::exec::par::PAR_MIN_WORK {
            gemm_acc(x, &self.w, out, bsz, din, dout);
            for r in 0..bsz {
                let row = &mut out[r * dout..(r + 1) * dout];
                for (o, &bb) in row.iter_mut().zip(&self.b) {
                    *o += bb;
                }
            }
            return;
        }
        par_row_blocks(pool, out, dout, |row0, oblock| {
            let rows = oblock.len() / dout;
            gemm_acc(&x[row0 * din..(row0 + rows) * din], &self.w, oblock, rows, din, dout);
            for orow in oblock.chunks_mut(dout) {
                for (o, &bb) in orow.iter_mut().zip(&self.b) {
                    *o += bb;
                }
            }
        });
    }

    /// Backward + SGD: given dL/dout, produce dL/dx and update W, b.
    /// dx is row-sharded; dW is column-sharded (`par_gemm_at_overwrite`),
    /// both bit-identical to serial; db + the weight update stay serial.
    fn backward_sgd(
        &mut self,
        pool: &ExecPool,
        x: &[f32],
        dout: &[f32],
        dx: &mut [f32],
        bsz: usize,
        lr: f32,
    ) {
        // dx = dout · Wᵀ
        dx.fill(0.0);
        par_gemm_bt_acc(pool, dout, &self.w, dx, bsz, self.dout, self.din);
        // dW = xᵀ · dout ; apply fused with -lr
        let mut dw = vec![0.0; self.din * self.dout];
        par_gemm_at_overwrite(pool, x, dout, &mut dw, self.din, bsz, self.dout);
        axpy(&mut self.w, -lr, &dw);
        // db = Σ_b dout
        for r in 0..bsz {
            let row = &dout[r * self.dout..(r + 1) * self.dout];
            for (bb, &g) in self.b.iter_mut().zip(row) {
                *bb -= lr * g;
            }
        }
    }
}

/// Fall back to a serial pool when the estimated multiply-add volume is
/// too small for thread spawns to pay off (results are bit-identical
/// either way; this is purely a perf gate).
fn work_gated(pool: &ExecPool, work: usize) -> ExecPool {
    if work < crate::exec::par::PAR_MIN_WORK {
        ExecPool::serial()
    } else {
        *pool
    }
}

/// Embedding table slot: the paper's compression policy per table.
#[derive(Clone)]
pub enum TableSlot {
    Tt(EffTtTable),
    Plain(PlainTable),
}

impl TableSlot {
    pub fn bytes(&self) -> u64 {
        match self {
            TableSlot::Tt(t) => t.bytes(),
            TableSlot::Plain(t) => t.bytes(),
        }
    }
}

/// Engine configuration (mirrors `python/compile/model.py::ModelCfg`).
#[derive(Clone, Debug)]
pub struct EngineCfg {
    pub dense_dim: usize,
    pub emb_dim: usize,
    /// (rows, compressed?) per sparse feature.
    pub tables: Vec<(u64, bool)>,
    pub tt_rank: usize,
    pub bot_hidden: Vec<usize>,
    pub top_hidden: Vec<usize>,
    pub lr: f32,
    pub tt_opts: EffTtOptions,
    /// Intra-step parallelism (exec layer); serial by default, and every
    /// worker count produces bit-identical results.
    pub exec: ExecCfg,
}

impl EngineCfg {
    /// IEEE118 detection model at `scale` (matches `model.ieee118_cfg`).
    pub fn ieee118(scale: f64) -> EngineCfg {
        let s = |r: f64| ((r * scale) as u64).max(32);
        EngineCfg {
            dense_dim: 6,
            emb_dim: 16,
            tables: vec![
                (s(12_000_000.0), true),
                (s(7_500_000.0), true),
                (118, false),
                (186, false),
                (54, false),
                (24, false),
                (91, false),
            ],
            tt_rank: 8,
            bot_hidden: vec![64, 32],
            top_hidden: vec![64, 32],
            lr: 0.05,
            tt_opts: EffTtOptions::default(),
            exec: ExecCfg::default(),
        }
    }

    pub fn n_tables(&self) -> usize {
        self.tables.len()
    }

    fn n_feat(&self) -> usize {
        self.n_tables() + 1
    }

    pub fn n_inter(&self) -> usize {
        let f = self.n_feat();
        f * (f - 1) / 2
    }
}

/// Reusable forward/backward scratch (allocation-free steady state).
#[derive(Clone, Default)]
struct EngineScratch {
    acts_bot: Vec<Vec<f32>>,  // per bot layer output [b, dout]
    acts_top: Vec<Vec<f32>>,  // per top layer output
    z: Vec<f32>,              // [b, F, E] stacked features
    gram: Vec<f32>,           // [b, F, F]
    x_top: Vec<f32>,          // [b, E + n_inter]
    dlogits: Vec<f32>,        // [b]
    dz: Vec<f32>,
    dgram: Vec<f32>,
    dx: Vec<Vec<f32>>,        // ping-pong grads for MLP backward
    pooled: Vec<f32>,         // [b, E] per-table lookup output
    gemb: Vec<f32>,           // [b, E] per-table embedding grad
    tt: TtScratch,
    /// Inline access plan for the unplanned-API wrappers; built once per
    /// batch and shared by forward AND backward (the pre-refactor code
    /// re-derived the index work in each).
    plan: BatchPlan,
}

#[derive(Clone)]
pub struct NativeDlrm {
    pub cfg: EngineCfg,
    pub bot: Vec<DenseLayer>,
    pub top: Vec<DenseLayer>,
    pub tables: Vec<TableSlot>,
    scratch: EngineScratch,
    /// Per-slot TT shapes (`None` = plain) for inline plan building.
    table_shapes: Vec<Option<TtShapes>>,
    /// Shared exec pool; threaded into the MLPs, the interaction layer
    /// and every TT table.
    pool: ExecPool,
}

impl NativeDlrm {
    pub fn new(cfg: EngineCfg, rng: &mut Rng) -> NativeDlrm {
        let mut bot = Vec::new();
        let mut dims = vec![cfg.dense_dim];
        dims.extend(&cfg.bot_hidden);
        dims.push(cfg.emb_dim);
        for w in dims.windows(2) {
            bot.push(DenseLayer::new(w[0], w[1], rng));
        }
        let mut top = Vec::new();
        let mut dims = vec![cfg.emb_dim + cfg.n_inter()];
        dims.extend(&cfg.top_hidden);
        dims.push(1);
        for w in dims.windows(2) {
            top.push(DenseLayer::new(w[0], w[1], rng));
        }
        let pool = ExecPool::new(cfg.exec);
        let tables = cfg
            .tables
            .iter()
            .map(|&(rows, compressed)| {
                if compressed {
                    let shapes = TtShapes::plan(rows, cfg.emb_dim, cfg.tt_rank);
                    let mut t = EffTtTable::new(shapes, cfg.tt_opts, rng);
                    t.set_pool(pool);
                    TableSlot::Tt(t)
                } else {
                    TableSlot::Plain(PlainTable::new(rows, cfg.emb_dim, rng))
                }
            })
            .collect();
        let table_shapes = crate::access::planner::table_shapes(&cfg);
        NativeDlrm {
            cfg,
            bot,
            top,
            tables,
            scratch: EngineScratch::default(),
            table_shapes,
            pool,
        }
    }

    /// Per-slot TT shapes (`None` = plain slot) — what an external
    /// `AccessPlanner` must plan against to feed this engine.
    pub fn table_shapes(&self) -> &[Option<TtShapes>] {
        &self.table_shapes
    }

    /// Re-target the exec layer (e.g. a bench switching workers=1 vs N,
    /// or serve replicas pinning one worker each).  Results stay
    /// bit-identical across worker counts by construction.
    pub fn set_workers(&mut self, workers: usize) {
        self.cfg.exec = ExecCfg::with_workers(workers);
        self.pool = ExecPool::new(self.cfg.exec);
        for t in &mut self.tables {
            if let TableSlot::Tt(tt) = t {
                tt.set_pool(self.pool);
            }
        }
    }

    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Freeze (or thaw, with [`QuantizeMode::Off`]) every TT table into
    /// the quantized serving representation.  Plain slots are untouched.
    /// A frozen engine is forward-only; training panics until thawed.
    pub fn freeze_quantized(&mut self, mode: QuantizeMode) {
        for t in &mut self.tables {
            if let TableSlot::Tt(tt) = t {
                tt.freeze_quantized(mode);
            }
        }
    }

    /// Total embedding-parameter bytes (Table IV / VI accounting).
    pub fn embedding_bytes(&self) -> u64 {
        self.tables.iter().map(|t| t.bytes()).sum()
    }

    /// Total model bytes including MLPs.
    pub fn model_bytes(&self) -> u64 {
        let mlp: usize = self
            .bot
            .iter()
            .chain(&self.top)
            .map(|l| (l.w.len() + l.b.len()) * 4)
            .sum();
        self.embedding_bytes() + mlp as u64
    }

    /// Forward pass; fills logits [b].  Thin wrapper over
    /// [`NativeDlrm::forward_planned`]: builds the access plan inline
    /// (identity remap) into reusable scratch — bit-identical to feeding
    /// a plan from the ingest stage.
    pub fn forward(&mut self, batch: &Batch, logits: &mut Vec<f32>) {
        let mut plan = std::mem::take(&mut self.scratch.plan);
        plan.build_into(batch, &self.table_shapes, &[]);
        self.forward_planned(batch, &plan, logits);
        self.scratch.plan = plan;
    }

    /// Plan-accepting forward pass.  `plan` must have been built over
    /// this `batch` (columns remapped by whatever bijections the planner
    /// holds) against this engine's [`NativeDlrm::table_shapes`]; the
    /// engine reads sparse indices exclusively through it.
    pub fn forward_planned(&mut self, batch: &Batch, plan: &BatchPlan, logits: &mut Vec<f32>) {
        let b = batch.batch_size;
        debug_assert_eq!(plan.batch_size(), b, "plan built for a different batch");
        let cfg = &self.cfg;
        let e = cfg.emb_dim;
        let nf = cfg.n_feat();
        let pool = self.pool;
        let scratch = &mut self.scratch;

        // ---- bottom MLP (ReLU after every layer incl. last) -------------
        scratch.acts_bot.resize(self.bot.len(), Vec::new());
        for (li, layer) in self.bot.iter().enumerate() {
            let (done, rest) = scratch.acts_bot.split_at_mut(li);
            let input: &[f32] = if li == 0 { &batch.dense } else { &done[li - 1] };
            let out = &mut rest[0];
            out.resize(b * layer.dout, 0.0);
            layer.forward(&pool, input, out, b);
            for v in out.iter_mut() {
                *v = v.max(0.0);
            }
        }

        // ---- embeddings -> stacked z [b, F, E] ---------------------------
        scratch.z.resize(b * nf * e, 0.0);
        let z0 = scratch.acts_bot.last().unwrap();
        for r in 0..b {
            scratch.z[r * nf * e..r * nf * e + e].copy_from_slice(&z0[r * e..(r + 1) * e]);
        }
        let ns = cfg.n_tables();
        scratch.pooled.resize(b * e, 0.0);
        for t in 0..ns {
            // sparse indices come exclusively from the plan: columns are
            // pre-extracted (and pre-remapped), TT dedup is precomputed,
            // and unit-bag offsets are a cached slice instead of a fresh
            // `(0..=b)` vector per call.  A TT slot without a plan (the
            // planner skips slots whose opts never consult one) falls
            // back to the inline-wrapper path.
            match &mut self.tables[t] {
                TableSlot::Tt(tab) => match plan.tt_plan(t) {
                    Some(tp) => tab.embedding_bag_planned(
                        plan.col(t),
                        BagLayout::Unit(b),
                        tp,
                        &mut scratch.pooled,
                        &mut scratch.tt,
                    ),
                    None => tab.embedding_bag(
                        plan.col(t),
                        plan.offsets(),
                        &mut scratch.pooled,
                        &mut scratch.tt,
                    ),
                },
                TableSlot::Plain(tab) => {
                    tab.embedding_bag(plan.col(t), plan.offsets(), &mut scratch.pooled)
                }
            }
            for r in 0..b {
                let dst = r * nf * e + (t + 1) * e;
                scratch.z[dst..dst + e]
                    .copy_from_slice(&scratch.pooled[r * e..(r + 1) * e]);
            }
        }

        // ---- interaction: gram + lower triangle (row-sharded) -----------
        scratch.gram.resize(b * nf * nf, 0.0);
        {
            let z = &scratch.z;
            let pool = work_gated(&pool, b * nf * nf * e);
            par_row_blocks(&pool, &mut scratch.gram, nf * nf, |r0, gblock| {
                for (i, gr) in gblock.chunks_mut(nf * nf).enumerate() {
                    let r = r0 + i;
                    let zr = &z[r * nf * e..(r + 1) * nf * e];
                    gr.fill(0.0);
                    gemm_bt_acc(zr, zr, gr, nf, e, nf);
                }
            });
        }
        let ni = cfg.n_inter();
        scratch.x_top.resize(b * (e + ni), 0.0);
        for r in 0..b {
            let dst = &mut scratch.x_top[r * (e + ni)..(r + 1) * (e + ni)];
            dst[..e].copy_from_slice(&z0[r * e..(r + 1) * e]);
            let gr = &scratch.gram[r * nf * nf..(r + 1) * nf * nf];
            let mut k = 0;
            for i in 1..nf {
                for j in 0..i {
                    dst[e + k] = gr[i * nf + j];
                    k += 1;
                }
            }
        }

        // ---- top MLP -----------------------------------------------------
        scratch.acts_top.resize(self.top.len(), Vec::new());
        let nl = self.top.len();
        for (li, layer) in self.top.iter().enumerate() {
            let (done, rest) = scratch.acts_top.split_at_mut(li);
            let input: &[f32] = if li == 0 { &scratch.x_top } else { &done[li - 1] };
            let out = &mut rest[0];
            out.resize(b * layer.dout, 0.0);
            layer.forward(&pool, input, out, b);
            if li + 1 < nl {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
        logits.clear();
        logits.extend_from_slice(scratch.acts_top.last().unwrap());
    }

    /// Forward-only predictions (serving path).
    pub fn predict(&mut self, batch: &Batch) -> Vec<f32> {
        let mut logits = Vec::new();
        self.forward(batch, &mut logits);
        logits.iter().map(|&l| 1.0 / (1.0 + (-l).exp())).collect()
    }

    /// Plan-accepting predictions: the serving path hands in per-replica
    /// plan scratch so batch-1 requests allocate nothing.
    pub fn predict_planned(&mut self, batch: &Batch, plan: &BatchPlan) -> Vec<f32> {
        let mut logits = Vec::new();
        self.forward_planned(batch, plan, &mut logits);
        logits.iter().map(|&l| 1.0 / (1.0 + (-l).exp())).collect()
    }

    /// One SGD step: forward, BCE, backward through every component.
    /// Returns the mean batch loss.
    ///
    /// Thin wrapper over [`NativeDlrm::train_step_planned`]: the plan is
    /// built inline ONCE and shared by the forward and backward passes
    /// (the pre-refactor code re-extracted columns and re-sorted the
    /// occurrence list in each).
    pub fn train_step(&mut self, batch: &Batch) -> f32 {
        let mut plan = std::mem::take(&mut self.scratch.plan);
        plan.build_into(batch, &self.table_shapes, &[]);
        let loss = self.train_step_planned(batch, &plan);
        self.scratch.plan = plan;
        loss
    }

    /// Plan-accepting SGD step (see [`NativeDlrm::forward_planned`] for
    /// the plan contract).
    pub fn train_step_planned(&mut self, batch: &Batch, plan: &BatchPlan) -> f32 {
        let b = batch.batch_size;
        let lr = self.cfg.lr;
        let e = self.cfg.emb_dim;
        let nf = self.cfg.n_feat();
        let ni = self.cfg.n_inter();
        let ns = self.cfg.n_tables();
        let pool = self.pool;

        let mut logits = Vec::new();
        self.forward_planned(batch, plan, &mut logits);

        // BCE-with-logits loss + dL/dlogit = (σ(l) − y)/b
        let mut loss = 0.0f32;
        let scratch = &mut self.scratch;
        scratch.dlogits.resize(b, 0.0);
        for r in 0..b {
            let l = logits[r];
            let y = batch.labels[r];
            loss += l.max(0.0) - l * y + (-l.abs()).exp().ln_1p();
            let sig = 1.0 / (1.0 + (-l).exp());
            scratch.dlogits[r] = (sig - y) / b as f32;
        }
        loss /= b as f32;

        // ---- top MLP backward -------------------------------------------
        scratch.dx.resize(2, Vec::new());
        let mut dout = std::mem::take(&mut scratch.dx[0]);
        dout.clear();
        dout.extend_from_slice(&scratch.dlogits); // [b, 1]
        let mut dxbuf = std::mem::take(&mut scratch.dx[1]);
        let nl = self.top.len();
        for li in (0..nl).rev() {
            // input to layer li
            let x_owned;
            let x: &[f32] = if li == 0 {
                &scratch.x_top
            } else {
                x_owned = &scratch.acts_top[li - 1];
                x_owned
            };
            // relu grad (no relu on the final layer's output)
            if li + 1 < nl {
                // dout currently is grad wrt post-ReLU output of layer li;
                // mask by activation > 0
                let act = &scratch.acts_top[li];
                for (g, &a) in dout.iter_mut().zip(act.iter()) {
                    if a <= 0.0 {
                        *g = 0.0;
                    }
                }
            }
            dxbuf.resize(b * self.top[li].din, 0.0);
            self.top[li].backward_sgd(&pool, x, &dout, &mut dxbuf, b, lr);
            std::mem::swap(&mut dout, &mut dxbuf);
        }
        // dout is now d x_top [b, e + ni]

        // ---- interaction backward ----------------------------------------
        // dgram from the lower-triangle slots; dz = (dG + dGᵀ)·z
        scratch.dgram.resize(b * nf * nf, 0.0);
        scratch.dgram.fill(0.0);
        for r in 0..b {
            let src = &dout[r * (e + ni) + e..(r + 1) * (e + ni)];
            let gr = &mut scratch.dgram[r * nf * nf..(r + 1) * nf * nf];
            let mut k = 0;
            for i in 1..nf {
                for j in 0..i {
                    gr[i * nf + j] = src[k];
                    k += 1;
                }
            }
        }
        scratch.dz.resize(b * nf * e, 0.0);
        scratch.dz.fill(0.0);
        {
            let z = &scratch.z;
            let dgram = &scratch.dgram;
            let pool = work_gated(&pool, b * nf * nf * e);
            par_row_blocks(&pool, &mut scratch.dz, nf * e, |r0, dzblock| {
                // sym = G + Gᵀ, then dz = sym · z — per sample row
                let mut sym = vec![0.0f32; nf * nf];
                for (i, dzr) in dzblock.chunks_mut(nf * e).enumerate() {
                    let r = r0 + i;
                    let gr = &dgram[r * nf * nf..(r + 1) * nf * nf];
                    let zr = &z[r * nf * e..(r + 1) * nf * e];
                    for ii in 0..nf {
                        for jj in 0..nf {
                            sym[ii * nf + jj] = gr[ii * nf + jj] + gr[jj * nf + ii];
                        }
                    }
                    gemm_acc(&sym, zr, dzr, nf, nf, e);
                }
            });
        }

        // ---- embedding backward ------------------------------------------
        // columns, dedup and aggregation order all come from the plan —
        // built once per batch, shared with the forward pass
        scratch.gemb.resize(b * e, 0.0);
        for t in 0..ns {
            for r in 0..b {
                let src = r * nf * e + (t + 1) * e;
                scratch.gemb[r * e..(r + 1) * e]
                    .copy_from_slice(&scratch.dz[src..src + e]);
            }
            match &mut self.tables[t] {
                TableSlot::Tt(tab) => match plan.tt_plan(t) {
                    Some(tp) => tab.backward_sgd_planned(
                        plan.col(t),
                        BagLayout::Unit(b),
                        tp,
                        &scratch.gemb,
                        lr,
                        &mut scratch.tt,
                    ),
                    None => tab.backward_sgd(
                        plan.col(t),
                        plan.offsets(),
                        &scratch.gemb,
                        lr,
                        &mut scratch.tt,
                    ),
                },
                TableSlot::Plain(tab) => {
                    tab.backward_sgd(plan.col(t), plan.offsets(), &scratch.gemb, lr)
                }
            }
        }

        // ---- bottom MLP backward -----------------------------------------
        // dz0 = dz[:, 0, :] + dout[:, :e] (concat + interaction paths)
        let mut dbot = vec![0.0f32; b * e];
        for r in 0..b {
            let dst = &mut dbot[r * e..(r + 1) * e];
            dst.copy_from_slice(&scratch.dz[r * nf * e..r * nf * e + e]);
            let src = &dout[r * (e + ni)..r * (e + ni) + e];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        let mut g = dbot;
        let nb = self.bot.len();
        for li in (0..nb).rev() {
            // all bottom layers (incl. the last) apply ReLU
            let act = &scratch.acts_bot[li];
            for (gv, &a) in g.iter_mut().zip(act.iter()) {
                if a <= 0.0 {
                    *gv = 0.0;
                }
            }
            let x_owned;
            let x: &[f32] = if li == 0 {
                &batch.dense
            } else {
                x_owned = &scratch.acts_bot[li - 1];
                x_owned
            };
            dxbuf.resize(b * self.bot[li].din, 0.0);
            self.bot[li].backward_sgd(&pool, x, &g, &mut dxbuf, b, lr);
            std::mem::swap(&mut g, &mut dxbuf);
        }

        scratch.dx[0] = dout;
        scratch.dx[1] = dxbuf;
        loss
    }

    /// Sum of stats across TT tables (ablation instrumentation).
    pub fn tt_stats(&self) -> crate::tt::table::TtStats {
        let mut s = crate::tt::table::TtStats::default();
        for t in &self.tables {
            if let TableSlot::Tt(tt) = t {
                s.add(&tt.stats);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ctr::Batch;

    fn tiny_cfg() -> EngineCfg {
        EngineCfg {
            dense_dim: 4,
            emb_dim: 8,
            tables: vec![(500, true), (300, true), (20, false)],
            tt_rank: 4,
            bot_hidden: vec![16],
            top_hidden: vec![16],
            lr: 0.1,
            tt_opts: EffTtOptions::default(),
            exec: ExecCfg::default(),
        }
    }

    fn tiny_batch(cfg: &EngineCfg, b: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed);
        let ns = cfg.n_tables();
        let mut dense = vec![0.0; b * cfg.dense_dim];
        rng.fill_normal(&mut dense, 0.0, 1.0);
        let sparse: Vec<u64> = (0..b * ns)
            .map(|i| rng.below(cfg.tables[i % ns].0))
            .collect();
        let labels: Vec<f32> = (0..b).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect();
        Batch { dense, sparse, labels, batch_size: b }
    }

    #[test]
    fn forward_shapes_and_probs() {
        let cfg = tiny_cfg();
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let batch = tiny_batch(&cfg, 6, 2);
        let probs = m.predict(&batch);
        assert_eq!(probs.len(), 6);
        for &p in &probs {
            assert!((0.0..=1.0).contains(&p), "{p}");
        }
    }

    #[test]
    fn train_overfits_small_batch() {
        let cfg = tiny_cfg();
        let mut m = NativeDlrm::new(cfg.clone(), &mut Rng::new(3));
        let batch = tiny_batch(&cfg, 16, 4);
        let first = m.train_step(&batch);
        let mut last = first;
        for _ in 0..150 {
            last = m.train_step(&batch);
        }
        assert!(
            last < 0.25 * first,
            "no overfit: {first} -> {last} (engine backward broken?)"
        );
    }

    /// Finite-difference gradient check through the ENTIRE engine: bump a
    /// weight, verify the loss moves as the analytic gradient predicts.
    #[test]
    fn gradcheck_bottom_weight() {
        let cfg = tiny_cfg();
        let batch = tiny_batch(&cfg, 4, 7);
        let eps = 1e-3f32;

        let loss_of = |m: &mut NativeDlrm| -> f32 {
            let mut logits = Vec::new();
            m.forward(&batch, &mut logits);
            let mut loss = 0.0;
            for r in 0..batch.batch_size {
                let l = logits[r];
                let y = batch.labels[r];
                loss += l.max(0.0) - l * y + (-l.abs()).exp().ln_1p();
            }
            loss / batch.batch_size as f32
        };

        for probe in [0usize, 5, 11] {
            // numeric
            let mut mp = NativeDlrm::new(cfg.clone(), &mut Rng::new(42));
            mp.bot[0].w[probe] += eps;
            let fp = loss_of(&mut mp);
            let mut mm = NativeDlrm::new(cfg.clone(), &mut Rng::new(42));
            mm.bot[0].w[probe] -= eps;
            let fm = loss_of(&mut mm);
            let numeric = (fp - fm) / (2.0 * eps);
            // analytic: value moved by one SGD step = -lr * grad
            let mut ma = NativeDlrm::new(cfg.clone(), &mut Rng::new(42));
            let w0 = ma.bot[0].w[probe];
            ma.train_step(&batch);
            let analytic = (w0 - ma.bot[0].w[probe]) / cfg.lr;
            assert!(
                (numeric - analytic).abs() < 2e-2 * numeric.abs().max(0.1),
                "probe {probe}: numeric {numeric} analytic {analytic}"
            );
        }
    }

    #[test]
    fn footprint_accounting() {
        let cfg = tiny_cfg();
        let m = NativeDlrm::new(cfg, &mut Rng::new(1));
        // TT tables must be smaller than their plain equivalents
        let tt_bytes = m.embedding_bytes();
        let plain_equiv: u64 = (500 + 300 + 20) * 8 * 4;
        assert!(tt_bytes < plain_equiv, "{tt_bytes} >= {plain_equiv}");
        assert!(m.model_bytes() > tt_bytes);
    }
}
