//! The Rec-AD coordinator (paper §IV): pipeline PS training, embedding
//! cache with RAW synchronization, device/cost platform model, native
//! compute engine, and the all-reduce used by multi-device arms.

pub mod allreduce;
pub mod cache;
pub mod data_parallel;
pub mod engine;
pub mod params;
pub mod pipeline;
pub mod platform;
pub mod queues;
pub mod trainer;

pub use allreduce::{AllReduce, SparseDelta, StragglerCarry};
pub use cache::{EmbeddingCache, PrefetchBatch, PrefetchedRow};
pub use data_parallel::{
    train_data_parallel, train_data_parallel_faulted, train_data_parallel_placed,
    DataParallelReport, DpCfg, Placement,
};
pub use engine::{EngineCfg, NativeDlrm, TableSlot};
pub use params::{GradPacket, HostParams};
pub use pipeline::{run as run_pipeline, PipelineCfg, PipelineReport};
pub use platform::{CostModel, SimPlatform};
pub use queues::BoundedQueue;
