//! High-level training drivers gluing dataset → engine → metrics.
//! Used by the Table III/V benches, the CLI `train` subcommand, and the
//! examples.

use std::sync::Arc;
use std::time::Duration;

use crate::access::{run_prefetched_fill, AccessCfg, AccessPlanner, BatchPlan};
use crate::coordinator::data_parallel::{
    train_data_parallel_faulted, DataParallelReport, DpCfg,
};
use crate::runtime::fault::FaultPlan;
use crate::coordinator::engine::{EngineCfg, NativeDlrm};
use crate::data::batcher::{fill_batch, EpochIter};
use crate::data::ctr::Batch;
use crate::metrics::classify::{evaluate, ClassifyReport};
use crate::powersys::dataset::{Ieee118Dataset, Sample};
use crate::runtime::autotune::AutotuneCfg;
use crate::util::clock::Clock;
use crate::util::prng::Rng;

#[derive(Debug)]
pub struct TrainReport {
    pub epochs: usize,
    pub steps: u64,
    pub wall: Duration,
    pub samples_per_sec: f64,
    pub loss_curve: Vec<f32>,
    pub eval: ClassifyReport,
    /// Longest single planning call on the ingest thread (seconds) —
    /// spikes when inline bijection rebuilds fire; the background
    /// refresh engine (`[access] background_reorder`) bounds it.
    pub plan_stall_max_s: f64,
}

/// Train a detector on the IEEE118 dataset and evaluate on the held-out
/// split.  Returns the trained engine for serving.  Ingest runs through
/// the access layer with the default lookahead; see
/// [`train_ieee118_with`] for explicit access-layer policy.
pub fn train_ieee118(
    cfg: EngineCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> (TrainReport, NativeDlrm) {
    train_ieee118_with(cfg, &AccessCfg::default(), dataset, epochs, batch_size, seed)
}

/// [`train_ieee118`] with an explicit access-layer policy: batches are
/// assembled + remapped + planned by the ingest stage (`access::ingest`)
/// — with `plan_ahead > 0` on a worker thread overlapping training, which
/// is bit-identical to inline planning by construction.
pub fn train_ieee118_with(
    cfg: EngineCfg,
    access: &AccessCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> (TrainReport, NativeDlrm) {
    let (report, engine, _) =
        train_ieee118_full(cfg, access, dataset, epochs, batch_size, seed);
    (report, engine)
}

/// [`train_ieee118_with`], additionally returning the planner the model
/// trained under — REQUIRED for serving whenever reordering is active
/// (profiled or online): the learned embedding rows are only consistent
/// with that planner's bijections, so hand it to
/// [`Detector::with_planner`](crate::serve::Detector::with_planner).
pub fn train_ieee118_full(
    cfg: EngineCfg,
    access: &AccessCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> (TrainReport, NativeDlrm, AccessPlanner) {
    train_ieee118_auto(cfg, access, &AutotuneCfg::default(), dataset, epochs, batch_size, seed)
}

/// [`train_ieee118_full`] with the self-tuning runtime attached.  With
/// `autotune.enabled = false` (the [`AutotuneCfg`] default) no tuner is
/// installed and no step is timed — the run is bit-identical to the
/// static path (pinned in `tests/autotune_equivalence.rs`).  When the
/// cache loop is on, the consume side times each `train_step_planned`
/// and feeds the seconds back to the planner's budget ladder; when the
/// reorder loop is on, each online slot's `refresh_every` follows its
/// plan's reuse-rate decay.
pub fn train_ieee118_auto(
    cfg: EngineCfg,
    access: &AccessCfg,
    autotune: &AutotuneCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> (TrainReport, NativeDlrm, AccessPlanner) {
    train_ieee118_auto_clocked(
        cfg, access, autotune, dataset, epochs, batch_size, seed, &Clock::real(),
    )
}

/// [`train_ieee118_auto`] with an injected [`Clock`] — the source for
/// the report's wall/throughput numbers and the cache loop's per-step
/// cost signal.  Tests pass [`Clock::manual`] for wall-clock-free runs.
#[allow(clippy::too_many_arguments)]
pub fn train_ieee118_auto_clocked(
    cfg: EngineCfg,
    access: &AccessCfg,
    autotune: &AutotuneCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    seed: u64,
    clock: &Clock,
) -> (TrainReport, NativeDlrm, AccessPlanner) {
    let (train, test) = dataset.split(0.8);
    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(seed));
    let mut planner = AccessPlanner::for_engine_cfg(&engine.cfg);
    planner.configure(&engine.cfg, access);
    planner.enable_autotune(autotune);
    let feedback = planner.cache_feedback();
    let mut rng = Rng::new(seed ^ 0xE90C);
    let mut loss_curve = Vec::new();
    let mut steps = 0u64;
    let mut plan_stall_max_s = 0.0f64;
    let t0 = clock.now();
    for _ in 0..epochs {
        let mut iter = EpochIter::new(train, batch_size, &mut rng);
        let report = run_prefetched_fill(
            |out| iter.next_into(out),
            &mut planner,
            access.plan_ahead,
            |batch, plan| {
                match &feedback {
                    Some(fb) => {
                        // cache loop on: the measured step time is the
                        // ladder's cost signal for this batch's budget
                        let ts = clock.now();
                        loss_curve.push(engine.train_step_planned(batch, plan));
                        fb.push((clock.now() - ts).max(0.0));
                    }
                    None => loss_curve.push(engine.train_step_planned(batch, plan)),
                }
                steps += 1;
            },
        );
        plan_stall_max_s = plan_stall_max_s.max(report.plan_stall_max_s);
    }
    let wall = Duration::from_secs_f64((clock.now() - t0).max(1e-12));
    // evaluate through the SAME (now frozen) remap the model was trained
    // under — with online reordering the bijection the trainer ended on
    // is the only one the learned embedding rows are consistent with
    let eval = evaluate_on_with(&mut engine, &planner, test);
    let report = TrainReport {
        epochs,
        steps,
        wall,
        samples_per_sec: (steps as usize * batch_size) as f64 / wall.as_secs_f64(),
        loss_curve,
        eval,
        plan_stall_max_s,
    };
    (report, engine, planner)
}

/// Multi-device training driver (paper Fig. 8): assemble the epoch
/// stream once, train it across `dp.workers` replica workers under
/// `dp.placement` (contiguous replicated shards, or plan-driven
/// prefix-group routing with the sparse TT-core exchange), then evaluate
/// the synchronized model on the held-out split.
pub fn train_ieee118_dp(
    cfg: EngineCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    dp: &DpCfg,
) -> (DataParallelReport, NativeDlrm, ClassifyReport) {
    train_ieee118_dp_faulted(cfg, dataset, epochs, batch_size, dp, None)
}

/// [`train_ieee118_dp`] under a chaos plan: stragglers miss the exchange
/// deadline (weight-0 exclusion + error-feedback carry) and a
/// permanently dead worker's shard is re-routed — see
/// [`train_data_parallel_faulted`].  With `fault` `None` (or a plan
/// carrying no training faults) this IS `train_ieee118_dp`,
/// bit-identically.
pub fn train_ieee118_dp_faulted(
    cfg: EngineCfg,
    dataset: &Ieee118Dataset,
    epochs: usize,
    batch_size: usize,
    dp: &DpCfg,
    fault: Option<&Arc<FaultPlan>>,
) -> (DataParallelReport, NativeDlrm, ClassifyReport) {
    let (train, test) = dataset.split(0.8);
    let mut rng = Rng::new(dp.seed ^ 0xE90C);
    let mut batches = Vec::new();
    for _ in 0..epochs {
        batches.extend(EpochIter::new(train, batch_size, &mut rng));
    }
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let (report, mut engine) =
        train_data_parallel_faulted(cfg, &planner, &batches, dp, fault);
    let eval = evaluate_on_with(&mut engine, &planner, test);
    (report, engine, eval)
}

/// Evaluate a trained engine on a sample slice (identity index mapping).
pub fn evaluate_on(engine: &mut NativeDlrm, samples: &[Sample]) -> ClassifyReport {
    let planner = AccessPlanner::for_engine_cfg(&engine.cfg);
    evaluate_on_with(engine, &planner, samples)
}

/// Evaluate through a planner's CURRENT bijections (frozen — evaluation
/// never advances online-reorder state).  Must be the planner the engine
/// trained under whenever reordering is active; with an identity planner
/// this is bit-identical to [`evaluate_on`]'s historical behavior.
pub fn evaluate_on_with(
    engine: &mut NativeDlrm,
    planner: &AccessPlanner,
    samples: &[Sample],
) -> ClassifyReport {
    let mut probs = Vec::with_capacity(samples.len());
    let mut labels = Vec::with_capacity(samples.len());
    let mut batch = Batch::default();
    let mut plan = BatchPlan::default();
    for chunk in samples.chunks(256) {
        fill_batch(chunk, &mut batch);
        planner.plan_frozen_into(&batch, &mut plan);
        probs.extend(engine.predict_planned(&batch, &plan));
        labels.extend(chunk.iter().map(|s| s.label));
    }
    evaluate(&probs, &labels, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};

    #[test]
    fn trains_and_beats_chance_on_ieee118() {
        let ds = generate(&DatasetCfg {
            n_normal: 800,
            n_attack: 200,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 30,
            noise_std: 0.005,
            seed: 7,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let (report, _) = train_ieee118(cfg, &ds, 3, 32, 1);
        // loss must descend and accuracy beat the 80% majority class
        let head: f32 = report.loss_curve[..5].iter().sum::<f32>() / 5.0;
        let n = report.loss_curve.len();
        let tail: f32 = report.loss_curve[n - 5..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "loss {head} -> {tail}");
        assert!(
            report.eval.accuracy > 0.8,
            "accuracy {} not above majority baseline",
            report.eval.accuracy
        );
        assert!(report.samples_per_sec > 0.0);
    }
}
