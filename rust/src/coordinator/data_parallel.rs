//! Real multi-worker data-parallel training (paper Fig. 8's ALLReduce
//! arm, executed with actual OS threads rather than the analytic
//! composition of `baselines::multi_gpu`).
//!
//! Every worker owns a full replica (MLPs + Eff-TT cores — small enough
//! to replicate, which is Rec-AD's §V-H scalability argument), consumes
//! its shard of each global batch, and synchronizes the *parameter
//! deltas* after each step: with SGD, the shard-size weighted mean of
//! post-step parameters from a common starting point is exactly
//! global-batch SGD (weighting is what keeps that identity when
//! `batch_size % n_workers != 0` — uniform averaging over uneven shards
//! is not global-batch SGD).
//!
//! Two [`Placement`] policies decide how a global batch maps to workers:
//!
//! * [`Placement::Replicated`] — contiguous shards (remainder spread one
//!   sample per leading worker) and a dense all-reduce of the FULL
//!   parameter vector.  The historical behavior, now deterministic: at
//!   one worker it is bit-identical to plain SGD (pinned); at n > 1 on
//!   even batches it computes the same mean the old code did, in a fixed
//!   merge order instead of the old nondeterministic arrival order (and
//!   reported losses are now the shard-size-weighted global-batch loss).
//! * [`Placement::Plan`] — **plan-driven device placement**: samples are
//!   routed through an [`AccessPlanner`]'s [`PlacementMap`], which mixes
//!   every compressed slot's post-bijection TT prefix into one key, so
//!   samples sharing ALL their TT prefixes always co-locate.  With a
//!   single compressed table that gives each prefix group exactly one
//!   owning worker; with several, a group of one table can still be
//!   touched by multiple workers (its samples may differ in the other
//!   tables' prefixes) — routing reduces, not eliminates, cross-worker
//!   repetition.  Dense MLPs (+ plain tables) stay replicated behind
//!   the same weighted all-reduce, while TT-core gradients travel
//!   through [`AllReduce::allreduce_sparse`] as `(offset, delta)` runs
//!   covering only the core slices each worker's shard touched, so the
//!   exchange volume drops well below the dense payload (touched-slice
//!   sparsity always; reduced duplication on top where ownership is
//!   exclusive).  In exact arithmetic both placements compute the same
//!   global-batch step; `tests/placement_equivalence.rs` pins
//!   bit-identity at one worker and convergence-equivalence at 2/4.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::access::planner::{AccessPlanner, PlacementMap};
use crate::coordinator::allreduce::{AllReduce, SparseDelta, SparseDeltaQ8, StragglerCarry};
use crate::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use crate::coordinator::platform::{CostModel, SimPlatform};
use crate::data::ctr::Batch;
use crate::runtime::fault::FaultPlan;
use crate::util::prng::Rng;

/// How a global batch (and the parameter exchange) maps onto workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Contiguous shards + dense full-vector all-reduce (the default).
    Replicated,
    /// Plan-driven placement: prefix-group routing + sparse TT exchange.
    Plan,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement> {
        match s {
            "replicated" => Ok(Placement::Replicated),
            "plan" => Ok(Placement::Plan),
            other => bail!("unknown placement '{other}' (expected replicated|plan)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Placement::Replicated => "replicated",
            Placement::Plan => "plan",
        }
    }
}

/// Data-parallel run configuration.
#[derive(Clone, Copy, Debug)]
pub struct DpCfg {
    /// Requested worker count (clamped so no worker can see an empty
    /// contiguous shard: effective workers ≤ smallest batch size).
    pub workers: usize,
    pub placement: Placement,
    /// Interconnect model charged for every exchange.
    pub cost: CostModel,
    /// Replica init seed (identical across workers by construction).
    pub seed: u64,
    /// Ship the plan-placed TT delta runs int8-quantized (per-run
    /// symmetric scales, error-feedback residual retained on the sender
    /// so dropped mass re-enters the next step's delta).  Only the Plan
    /// placement at n > 1 exchanges sparse runs, so this is a no-op for
    /// Replicated and single-worker runs.
    pub quantize_comm: bool,
}

#[derive(Debug)]
pub struct DataParallelReport {
    /// Effective worker count (after clamping to the smallest batch).
    pub workers: usize,
    pub placement: Placement,
    pub steps: u64,
    pub wall: Duration,
    pub throughput: f64,
    /// Per-step GLOBAL-batch loss (shard-size weighted across workers).
    pub losses: Vec<f32>,
    /// Total logical all-reduce payload over the run, summed across
    /// workers and steps (0 at one worker — nothing is exchanged).
    /// Replicated ships the full flat vector per worker per step; plan
    /// placement ships the dense region per worker plus the sparse
    /// `(offset, delta)` runs.
    pub payload_bytes: u64,
}

/// Flatten all trainable parameters into one vector (dense payload of
/// the replicated exchange).
fn flatten(engine: &NativeDlrm, out: &mut Vec<f32>) {
    out.clear();
    for l in engine.bot.iter().chain(&engine.top) {
        out.extend_from_slice(&l.w);
        out.extend_from_slice(&l.b);
    }
    for t in &engine.tables {
        match t {
            TableSlot::Tt(t) => {
                out.extend_from_slice(&t.core1);
                out.extend_from_slice(&t.core2);
                out.extend_from_slice(&t.core3);
            }
            TableSlot::Plain(t) => out.extend_from_slice(&t.weights),
        }
    }
}

/// Write a flat parameter vector back into the engine.
fn unflatten(engine: &mut NativeDlrm, flat: &[f32]) {
    let mut at = 0usize;
    let mut take = |n: usize| -> &[f32] {
        let s = &flat[at..at + n];
        at += n;
        s
    };
    for l in engine.bot.iter_mut().chain(engine.top.iter_mut()) {
        let n = l.w.len();
        l.w.copy_from_slice(take(n));
        let n = l.b.len();
        l.b.copy_from_slice(take(n));
    }
    for t in engine.tables.iter_mut() {
        match t {
            TableSlot::Tt(t) => {
                let n = t.core1.len();
                t.core1.copy_from_slice(take(n));
                let n = t.core2.len();
                t.core2.copy_from_slice(take(n));
                let n = t.core3.len();
                t.core3.copy_from_slice(take(n));
            }
            TableSlot::Plain(t) => {
                let n = t.weights.len();
                t.weights.copy_from_slice(take(n));
            }
        }
    }
    assert_eq!(at, flat.len(), "flat parameter size drift");
}

/// Flatten the replicated-dense region of the plan-placed exchange: MLP
/// layers plus plain (uncompressed) tables.
fn flatten_dense(engine: &NativeDlrm, out: &mut Vec<f32>) {
    out.clear();
    for l in engine.bot.iter().chain(&engine.top) {
        out.extend_from_slice(&l.w);
        out.extend_from_slice(&l.b);
    }
    for t in &engine.tables {
        if let TableSlot::Plain(t) = t {
            out.extend_from_slice(&t.weights);
        }
    }
}

fn unflatten_dense(engine: &mut NativeDlrm, flat: &[f32]) {
    let mut at = 0usize;
    let mut take = |n: usize| -> &[f32] {
        let s = &flat[at..at + n];
        at += n;
        s
    };
    for l in engine.bot.iter_mut().chain(engine.top.iter_mut()) {
        let n = l.w.len();
        l.w.copy_from_slice(take(n));
        let n = l.b.len();
        l.b.copy_from_slice(take(n));
    }
    for t in engine.tables.iter_mut() {
        if let TableSlot::Plain(t) = t {
            let n = t.weights.len();
            t.weights.copy_from_slice(take(n));
        }
    }
    assert_eq!(at, flat.len(), "dense parameter size drift");
}

/// Flatten the owner-routed region: every TT table's cores, slot order.
fn flatten_tt(engine: &NativeDlrm, out: &mut Vec<f32>) {
    out.clear();
    for t in &engine.tables {
        if let TableSlot::Tt(t) = t {
            out.extend_from_slice(&t.core1);
            out.extend_from_slice(&t.core2);
            out.extend_from_slice(&t.core3);
        }
    }
}

fn unflatten_tt(engine: &mut NativeDlrm, flat: &[f32]) {
    let mut at = 0usize;
    let mut take = |n: usize| -> &[f32] {
        let s = &flat[at..at + n];
        at += n;
        s
    };
    for t in engine.tables.iter_mut() {
        if let TableSlot::Tt(t) = t {
            let n = t.core1.len();
            t.core1.copy_from_slice(take(n));
            let n = t.core2.len();
            t.core2.copy_from_slice(take(n));
            let n = t.core3.len();
            t.core3.copy_from_slice(take(n));
        }
    }
    assert_eq!(at, flat.len(), "tt parameter size drift");
}

/// Split a global batch into `n` contiguous shards.  The remainder is
/// spread one sample per leading worker, so shard sizes differ by at
/// most one (the old layout dumped the whole remainder on the last
/// worker AND weighted it equally in the reduce).
fn shard(batch: &Batch, n_sparse: usize, w: usize, n: usize) -> Batch {
    let b = batch.batch_size;
    let per = b / n;
    let rem = b % n;
    let lo = w * per + w.min(rem);
    let hi = lo + per + usize::from(w < rem);
    let nd = batch.dense.len() / b;
    Batch {
        dense: batch.dense[lo * nd..hi * nd].to_vec(),
        sparse: batch.sparse[lo * n_sparse..hi * n_sparse].to_vec(),
        labels: batch.labels[lo..hi].to_vec(),
        batch_size: hi - lo,
    }
}

/// Route every batch once: per batch, per worker, the owned sample
/// indices (original batch order — a pure function of the batch and the
/// frozen map, so all workers share one pre-pass instead of re-hashing
/// the whole batch n times).
fn route_batches(
    batches: &[Batch],
    n_sparse: usize,
    pm: &PlacementMap,
    n: usize,
) -> Vec<Vec<Vec<u32>>> {
    batches
        .iter()
        .map(|b| {
            let mut lists = vec![Vec::new(); n];
            for r in 0..b.batch_size {
                let w = pm.owner_of(&b.sparse[r * n_sparse..(r + 1) * n_sparse]);
                lists[w].push(r as u32);
            }
            lists
        })
        .collect()
}

/// Gather the selected samples of a batch into a new contiguous batch.
fn gather(batch: &Batch, n_sparse: usize, rows: &[u32]) -> Batch {
    let nd = batch.dense.len() / batch.batch_size;
    let mut dense = Vec::with_capacity(rows.len() * nd);
    let mut sparse = Vec::with_capacity(rows.len() * n_sparse);
    let mut labels = Vec::with_capacity(rows.len());
    for &r in rows {
        let r = r as usize;
        dense.extend_from_slice(&batch.dense[r * nd..(r + 1) * nd]);
        sparse.extend_from_slice(&batch.sparse[r * n_sparse..(r + 1) * n_sparse]);
        labels.push(batch.labels[r]);
    }
    Batch { dense, sparse, labels, batch_size: rows.len() }
}

/// Train `batches` across `n_workers` replicas with per-step all-reduce
/// (replicated placement, identity planner — the historical entry point).
pub fn train_data_parallel(
    cfg: EngineCfg,
    batches: &[Batch],
    n_workers: usize,
    cost: CostModel,
    seed: u64,
) -> DataParallelReport {
    let planner = AccessPlanner::for_engine_cfg(&cfg);
    let dp = DpCfg {
        workers: n_workers,
        placement: Placement::Replicated,
        cost,
        seed,
        quantize_comm: false,
    };
    train_data_parallel_placed(cfg, &planner, batches, &dp).0
}

/// Train `batches` across replicas under an explicit [`Placement`],
/// routing plan-placed shards through `planner`'s placement map (its
/// CURRENT bijections — the view serving routes by).  Returns the report
/// and the trained engine (all replicas hold identical parameters after
/// the final exchange; worker 0's is returned).
pub fn train_data_parallel_placed(
    cfg: EngineCfg,
    planner: &AccessPlanner,
    batches: &[Batch],
    dp: &DpCfg,
) -> (DataParallelReport, NativeDlrm) {
    assert!(dp.workers >= 1);
    assert!(!batches.is_empty(), "data-parallel training needs batches");
    let min_batch = batches.iter().map(|b| b.batch_size).min().unwrap();
    assert!(min_batch >= 1, "empty batch in the training stream");
    // clamp: more workers than samples would hand train_step zero-size
    // shards under contiguous sharding
    let n = dp.workers.min(min_batch);
    let n_sparse = cfg.n_tables();
    // plan placement at one worker degenerates to the replicated path
    // (one shard = the whole batch, nothing to exchange), so the routing
    // pre-pass only exists for n > 1
    let routing = (dp.placement == Placement::Plan && n > 1)
        .then(|| route_batches(batches, n_sparse, &planner.placement_map(n), n));

    // identical init across replicas: same seed
    let proto = NativeDlrm::new(cfg.clone(), &mut Rng::new(dp.seed));
    let mut probe = Vec::new();
    flatten(&proto, &mut probe);
    let payload = probe.len();
    flatten_dense(&proto, &mut probe);
    let dense_len = probe.len();
    let tt_len = payload - dense_len;
    let ar = AllReduce::new(n, payload, dp.cost);
    drop(proto);

    // lint:allow(D2) measured wall time of the real run IS the bench metric
    let t0 = Instant::now();
    let (losses, engine, payload_bytes) = std::thread::scope(|scope| {
        let routing = routing.as_deref();
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar: Arc<AllReduce> = Arc::clone(&ar);
                let cfg = cfg.clone();
                scope.spawn(move || {
                    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(dp.seed));
                    let mut flat = vec![0.0f32; payload];
                    let mut dense = vec![0.0f32; dense_len];
                    let mut base = vec![0.0f32; tt_len];
                    let mut post = vec![0.0f32; tt_len];
                    let mut delta = SparseDelta::default();
                    // error-feedback state for quantized comm: residual
                    // persists across steps so quantization error is
                    // re-injected instead of lost
                    let mut qdelta = SparseDeltaQ8::default();
                    let mut residual = vec![0.0f32; if dp.quantize_comm { tt_len } else { 0 }];
                    let mut my: Vec<(f32, u32)> = Vec::with_capacity(batches.len());
                    let mut bytes = 0u64;
                    for (bi, batch) in batches.iter().enumerate() {
                        match routing {
                            None => {
                                let sb = shard(batch, n_sparse, w, n);
                                let loss = engine.train_step(&sb);
                                // shard-size weight, 1.0 exactly on even
                                // shards (the plain mean's arithmetic —
                                // no reweighting perturbation)
                                let weight = (sb.batch_size * n) as f64
                                    / batch.batch_size as f64;
                                // weighted mean of post-step params ==
                                // global-batch SGD (common start + SGD)
                                flatten(&engine, &mut flat);
                                ar.allreduce_weighted(w, &mut flat, weight as f32);
                                unflatten(&mut engine, &flat);
                                if w == 0 && n > 1 {
                                    bytes += (n * payload * 4) as u64;
                                }
                                my.push((loss, sb.batch_size as u32));
                            }
                            Some(routing) => {
                                let rows = &routing[bi][w];
                                let size = rows.len();
                                flatten_tt(&engine, &mut base);
                                let loss = if size > 0 {
                                    let sb = gather(batch, n_sparse, rows);
                                    engine.train_step(&sb)
                                } else {
                                    0.0 // weight 0 below: excluded
                                };
                                let weight = ((size * n) as f64
                                    / batch.batch_size as f64)
                                    as f32;
                                flatten_dense(&engine, &mut dense);
                                ar.allreduce_weighted(w, &mut dense, weight);
                                unflatten_dense(&mut engine, &dense);
                                flatten_tt(&engine, &mut post);
                                delta.diff(&base, &post);
                                let round = if dp.quantize_comm {
                                    qdelta.from_delta(&delta, &mut residual);
                                    ar.allreduce_sparse_q8(w, &mut base, &qdelta, weight)
                                } else {
                                    ar.allreduce_sparse(w, &mut base, &delta, weight)
                                };
                                unflatten_tt(&mut engine, &base);
                                if w == 0 {
                                    bytes += round + (n * dense_len * 4) as u64;
                                }
                                my.push((loss, size as u32));
                            }
                        }
                    }
                    (my, (w == 0).then_some(engine), bytes)
                })
            })
            .collect();
        let mut results: Vec<(Vec<(f32, u32)>, Option<NativeDlrm>, u64)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let payload_bytes: u64 = results.iter().map(|r| r.2).sum();
        let engine = results
            .iter_mut()
            .find_map(|r| r.1.take())
            .expect("worker 0 returns its engine");
        let all: Vec<Vec<(f32, u32)>> = results.into_iter().map(|r| r.0).collect();
        // per-step GLOBAL-batch loss: shard-size weighted mean (plain
        // per-worker losses are already per-sample means of their shard)
        let losses: Vec<f32> = (0..batches.len())
            .map(|s| {
                if n == 1 {
                    return all[0][s].0;
                }
                let total: f64 = all.iter().map(|l| l[s].1 as f64).sum();
                (all.iter().map(|l| l[s].0 as f64 * l[s].1 as f64).sum::<f64>()
                    / total.max(1.0)) as f32
            })
            .collect();
        (losses, engine, payload_bytes)
    });
    let wall = t0.elapsed();
    let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
    let report = DataParallelReport {
        workers: n,
        placement: dp.placement,
        steps: batches.len() as u64,
        wall,
        throughput: samples as f64 / wall.as_secs_f64(),
        losses,
        payload_bytes,
    };
    (report, engine)
}

/// Fault-tolerant variant of [`train_data_parallel_placed`]: same
/// arithmetic, plus two failure modes driven by a deterministic
/// [`FaultPlan`]:
///
/// * **Stragglers** — a worker whose round the plan marks late misses
///   the exchange deadline: it still hits every barrier (the simulated
///   communicator never loses a slot) but deposits with weight 0, so the
///   round's weighted mean is taken over the survivors only.  Its local
///   step is NOT thrown away: the (post − pre) progress is absorbed into
///   a [`StragglerCarry`] and folded back into its parameters at the
///   next round's start — the same error-feedback shape as
///   `allreduce_sparse_q8`'s residual, so missed work re-enters the
///   consensus one round late instead of vanishing.  If every live
///   worker would miss a round, nobody is excluded (the deadline is
///   effectively extended — a 0-weight-sum mean is undefined).
/// * **A permanently dead worker** — from its death round on, it trains
///   nothing and deposits weight 0 (keeping its barrier slot so the
///   group stays in lockstep, like a respawned-but-empty rank), and its
///   share of the data is re-routed: Replicated re-shards each batch
///   over the live workers; Plan moves the dead owner's rows to the next
///   worker (cyclic), deterministically.
///
/// With `fault` `None` — or a plan with no training faults configured —
/// this delegates straight to [`train_data_parallel_placed`]: the
/// fault-free path is the SAME code, bit-identical by construction
/// (pinned by `tests/fault_equivalence.rs`).
pub fn train_data_parallel_faulted(
    cfg: EngineCfg,
    planner: &AccessPlanner,
    batches: &[Batch],
    dp: &DpCfg,
    fault: Option<&Arc<FaultPlan>>,
) -> (DataParallelReport, NativeDlrm) {
    let plan = match fault {
        Some(f) if f.cfg().straggle_rate > 0.0 || f.cfg().dead_worker.is_some() => f,
        _ => return train_data_parallel_placed(cfg, planner, batches, dp),
    };
    assert!(dp.workers >= 1);
    assert!(!batches.is_empty(), "data-parallel training needs batches");
    let min_batch = batches.iter().map(|b| b.batch_size).min().unwrap();
    assert!(min_batch >= 1, "empty batch in the training stream");
    let n = dp.workers.min(min_batch);
    let n_sparse = cfg.n_tables();
    // the dead worker only exists if somebody can take over its shard
    let dead_cfg = plan.cfg().dead_worker.filter(|&dw| n > 1 && dw < n);
    let dead_round = plan.cfg().dead_round;
    let mut routing = (dp.placement == Placement::Plan && n > 1)
        .then(|| route_batches(batches, n_sparse, &planner.placement_map(n), n));
    // re-route the dead owner's rows to the next worker (cyclic) from its
    // death round on — a deterministic pre-pass all workers agree on
    if let (Some(routing), Some(dw)) = (routing.as_mut(), dead_cfg) {
        let target = (dw + 1) % n;
        for lists in routing.iter_mut().skip(dead_round as usize) {
            let moved = std::mem::take(&mut lists[dw]);
            lists[target].extend(moved);
            lists[target].sort_unstable();
        }
    }

    let proto = NativeDlrm::new(cfg.clone(), &mut Rng::new(dp.seed));
    let mut probe = Vec::new();
    flatten(&proto, &mut probe);
    let payload = probe.len();
    flatten_dense(&proto, &mut probe);
    let dense_len = probe.len();
    let tt_len = payload - dense_len;
    let ar = AllReduce::new(n, payload, dp.cost);
    drop(proto);

    // lint:allow(D2) measured wall time of the real run IS the bench metric
    let t0 = Instant::now();
    let (losses, engine, payload_bytes) = std::thread::scope(|scope| {
        let routing = routing.as_deref();
        let handles: Vec<_> = (0..n)
            .map(|w| {
                let ar: Arc<AllReduce> = Arc::clone(&ar);
                let cfg = cfg.clone();
                let f: &FaultPlan = plan;
                scope.spawn(move || {
                    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(dp.seed));
                    let mut flat = vec![0.0f32; payload];
                    let mut pre = vec![0.0f32; payload];
                    let mut dense = vec![0.0f32; dense_len];
                    let mut pre_dense = vec![0.0f32; dense_len];
                    let mut base = vec![0.0f32; tt_len];
                    let mut post = vec![0.0f32; tt_len];
                    let mut delta = SparseDelta::default();
                    let empty_delta = SparseDelta::default();
                    let empty_q = SparseDeltaQ8::default();
                    let mut qdelta = SparseDeltaQ8::default();
                    let mut residual = vec![0.0f32; if dp.quantize_comm { tt_len } else { 0 }];
                    // missed-round error feedback (replicated / plan split)
                    let mut carry = StragglerCarry::new(payload);
                    let mut carry_dense = StragglerCarry::new(dense_len);
                    let mut carry_tt = StragglerCarry::new(tt_len);
                    let mut my: Vec<(f32, u32)> = Vec::with_capacity(batches.len());
                    let mut bytes = 0u64;
                    for (bi, batch) in batches.iter().enumerate() {
                        let round = bi as u64;
                        let dead = dead_cfg == Some(w) && round >= dead_round;
                        if dead && round == dead_round {
                            f.record("dead", w, round);
                        }
                        // the straggler set is a pure function of the
                        // plan, so every worker derives the SAME excluded
                        // set (no timed rendezvous) — with an all-miss
                        // guard, and, under plan placement, a guard
                        // against rounds where every surviving shard is
                        // empty (either would zero the weight sum)
                        let live: Vec<usize> = (0..n)
                            .filter(|&ww| !(dead_cfg == Some(ww) && round >= dead_round))
                            .collect();
                        let all_miss = match routing {
                            None => live.iter().all(|&ww| f.straggle(ww, round)),
                            Some(routing) => {
                                let surviving_rows: usize = live
                                    .iter()
                                    .filter(|&&ww| !f.straggle(ww, round))
                                    .map(|&ww| routing[bi][ww].len())
                                    .sum();
                                surviving_rows == 0
                            }
                        };
                        let miss = !dead && !all_miss && f.straggle(w, round);
                        if miss {
                            f.record("straggle", w, round);
                            SimPlatform::charge(f.straggle_delay());
                        }
                        match routing {
                            None => {
                                // fold last round's missed progress back
                                // in before snapshotting the round base
                                flatten(&engine, &mut flat);
                                if carry.fold_into(&mut flat) {
                                    unflatten(&mut engine, &flat);
                                }
                                pre.copy_from_slice(&flat);
                                // the dead worker's shard is re-dealt
                                // over the live workers
                                let (n_live, pos) = match dead_cfg {
                                    Some(dw) if round >= dead_round => {
                                        (n - 1, if w > dw { w - 1 } else { w })
                                    }
                                    _ => (n, w),
                                };
                                let (loss, size) = if dead {
                                    (0.0, 0)
                                } else {
                                    let sb = shard(batch, n_sparse, pos, n_live);
                                    (engine.train_step(&sb), sb.batch_size)
                                };
                                flatten(&engine, &mut flat);
                                if miss {
                                    carry.absorb(&pre, &flat);
                                }
                                let weight = if dead || miss {
                                    0.0
                                } else {
                                    ((size * n_live) as f64 / batch.batch_size as f64) as f32
                                };
                                ar.allreduce_weighted(w, &mut flat, weight);
                                unflatten(&mut engine, &flat);
                                if w == 0 && n > 1 {
                                    bytes += (n * payload * 4) as u64;
                                }
                                my.push((loss, size as u32));
                            }
                            Some(routing) => {
                                flatten_dense(&engine, &mut dense);
                                if carry_dense.fold_into(&mut dense) {
                                    unflatten_dense(&mut engine, &dense);
                                }
                                pre_dense.copy_from_slice(&dense);
                                flatten_tt(&engine, &mut base);
                                if carry_tt.fold_into(&mut base) {
                                    unflatten_tt(&mut engine, &base);
                                }
                                // `base` = this round's common TT start
                                // (with any carried progress folded in)
                                let rows = &routing[bi][w];
                                let size = rows.len();
                                let loss = if size > 0 {
                                    let sb = gather(batch, n_sparse, rows);
                                    engine.train_step(&sb)
                                } else {
                                    0.0
                                };
                                let weight = if miss {
                                    0.0
                                } else {
                                    ((size * n) as f64 / batch.batch_size as f64) as f32
                                };
                                flatten_dense(&engine, &mut dense);
                                if miss {
                                    carry_dense.absorb(&pre_dense, &dense);
                                }
                                ar.allreduce_weighted(w, &mut dense, weight);
                                unflatten_dense(&mut engine, &dense);
                                flatten_tt(&engine, &mut post);
                                // a missed round ships an EMPTY delta
                                // (zero bytes, weight 0) and banks its
                                // local TT progress in the carry instead
                                let round_bytes = if miss {
                                    carry_tt.absorb(&base, &post);
                                    if dp.quantize_comm {
                                        ar.allreduce_sparse_q8(w, &mut base, &empty_q, 0.0)
                                    } else {
                                        ar.allreduce_sparse(w, &mut base, &empty_delta, 0.0)
                                    }
                                } else {
                                    delta.diff(&base, &post);
                                    if dp.quantize_comm {
                                        qdelta.from_delta(&delta, &mut residual);
                                        ar.allreduce_sparse_q8(w, &mut base, &qdelta, weight)
                                    } else {
                                        ar.allreduce_sparse(w, &mut base, &delta, weight)
                                    }
                                };
                                unflatten_tt(&mut engine, &base);
                                if w == 0 {
                                    bytes += round_bytes + (n * dense_len * 4) as u64;
                                }
                                my.push((loss, size as u32));
                            }
                        }
                    }
                    (my, (w == 0).then_some(engine), bytes)
                })
            })
            .collect();
        let mut results: Vec<(Vec<(f32, u32)>, Option<NativeDlrm>, u64)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let payload_bytes: u64 = results.iter().map(|r| r.2).sum();
        let engine = results
            .iter_mut()
            .find_map(|r| r.1.take())
            .expect("worker 0 returns its engine");
        let all: Vec<Vec<(f32, u32)>> = results.into_iter().map(|r| r.0).collect();
        let losses: Vec<f32> = (0..batches.len())
            .map(|s| {
                if n == 1 {
                    return all[0][s].0;
                }
                let total: f64 = all.iter().map(|l| l[s].1 as f64).sum();
                (all.iter().map(|l| l[s].0 as f64 * l[s].1 as f64).sum::<f64>()
                    / total.max(1.0)) as f32
            })
            .collect();
        (losses, engine, payload_bytes)
    });
    let wall = t0.elapsed();
    let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
    let report = DataParallelReport {
        workers: n,
        placement: dp.placement,
        steps: batches.len() as u64,
        wall,
        throughput: samples as f64 / wall.as_secs_f64(),
        losses,
        payload_bytes,
    };
    (report, engine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ctr::CtrGenerator;
    use crate::data::schema::DatasetSchema;
    use crate::tt::table::EffTtOptions;

    fn setup() -> (EngineCfg, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 4,
            emb_dim: 8,
            tables: vec![(1500, true), (60, false)],
            tt_rank: 4,
            bot_hidden: vec![16],
            top_hidden: vec![16],
            lr: 0.05,
            tt_opts: EffTtOptions::default(),
            exec: crate::exec::ExecCfg::default(),
        };
        let schema = DatasetSchema {
            name: "dp-test",
            n_dense: 4,
            vocabs: vec![1500, 60],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 11);
        (cfg, gen.batches(16, 32))
    }

    fn zero_cost() -> CostModel {
        CostModel {
            h2d_bps: 1e18,
            d2d_bps: 1e18,
            transfer_latency: Duration::ZERO,
            ps_row: Duration::ZERO,
            dispatch: Duration::ZERO,
        }
    }

    #[test]
    fn single_worker_equals_plain_training() {
        let (cfg, batches) = setup();
        let dp = train_data_parallel(cfg.clone(), &batches, 1, zero_cost(), 5);
        let mut engine = NativeDlrm::new(cfg, &mut Rng::new(5));
        let direct: Vec<f32> = batches.iter().map(|b| engine.train_step(b)).collect();
        assert_eq!(dp.losses, direct, "1-worker DP must equal plain SGD");
        assert_eq!(dp.payload_bytes, 0, "one worker exchanges nothing");
    }

    #[test]
    fn multi_worker_learns_and_stays_synchronized() {
        let (cfg, batches) = setup();
        let dp = train_data_parallel(cfg, &batches, 3, zero_cost(), 5);
        assert_eq!(dp.steps, 16);
        assert_eq!(dp.workers, 3);
        assert!(dp.payload_bytes > 0);
        let head = dp.losses[0];
        let tail = dp.losses[dp.losses.len() - 1];
        assert!(tail < head, "no learning under DP: {head} -> {tail}");
    }

    #[test]
    fn worker_count_clamps_to_smallest_batch() {
        let (cfg, _) = setup();
        let schema = DatasetSchema {
            name: "dp-tiny",
            n_dense: 4,
            vocabs: vec![1500, 60],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 3);
        let batches = gen.batches(4, 3); // 3 samples < 8 requested workers
        let dp = train_data_parallel(cfg, &batches, 8, zero_cost(), 5);
        assert_eq!(dp.workers, 3, "workers must clamp to the smallest batch");
        assert_eq!(dp.losses.len(), 4);
        assert!(dp.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let (cfg, _) = setup();
        let a = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut flat = Vec::new();
        flatten(&a, &mut flat);
        let mut b = NativeDlrm::new(cfg.clone(), &mut Rng::new(2));
        unflatten(&mut b, &flat);
        let mut flat_b = Vec::new();
        flatten(&b, &mut flat_b);
        assert_eq!(flat, flat_b);
        // the dense + tt split covers the same parameters, disjointly
        let mut dense = Vec::new();
        let mut tt = Vec::new();
        flatten_dense(&a, &mut dense);
        flatten_tt(&a, &mut tt);
        assert_eq!(dense.len() + tt.len(), flat.len());
        let mut c = NativeDlrm::new(cfg, &mut Rng::new(3));
        unflatten_dense(&mut c, &dense);
        unflatten_tt(&mut c, &tt);
        let mut flat_c = Vec::new();
        flatten(&c, &mut flat_c);
        assert_eq!(flat, flat_c, "dense+tt split must reassemble the full vector");
    }

    #[test]
    fn quantized_comm_shrinks_payload_and_still_learns() {
        let (cfg, batches) = setup();
        let planner = AccessPlanner::for_engine_cfg(&cfg);
        let mk = |q: bool| DpCfg {
            workers: 2,
            placement: Placement::Plan,
            cost: zero_cost(),
            seed: 5,
            quantize_comm: q,
        };
        let (f32_rep, _) =
            train_data_parallel_placed(cfg.clone(), &planner, &batches, &mk(false));
        let (q8_rep, _) =
            train_data_parallel_placed(cfg, &planner, &batches, &mk(true));
        assert!(
            q8_rep.payload_bytes < f32_rep.payload_bytes,
            "q8 {} must undercut f32 sparse {}",
            q8_rep.payload_bytes,
            f32_rep.payload_bytes
        );
        let head = q8_rep.losses[0];
        let tail = q8_rep.losses[q8_rep.losses.len() - 1];
        assert!(tail < head, "no learning under q8 comm: {head} -> {tail}");
        // error feedback keeps the trajectories close, not identical
        let f32_tail = f32_rep.losses[f32_rep.losses.len() - 1];
        assert!(
            (tail - f32_tail).abs() < 0.1,
            "q8 tail loss {tail} drifted from f32 {f32_tail}"
        );
    }

    #[test]
    fn faulted_with_no_training_faults_is_bit_identical_to_placed() {
        use crate::runtime::fault::FaultCfg;
        let (cfg, batches) = setup();
        let planner = AccessPlanner::for_engine_cfg(&cfg);
        for placement in [Placement::Replicated, Placement::Plan] {
            let dp = DpCfg {
                workers: 3,
                placement,
                cost: zero_cost(),
                seed: 5,
                quantize_comm: false,
            };
            let (base, _) =
                train_data_parallel_placed(cfg.clone(), &planner, &batches, &dp);
            let (none, _) =
                train_data_parallel_faulted(cfg.clone(), &planner, &batches, &dp, None);
            // a plan with serving faults only (no stragglers, no dead
            // worker) must not perturb training either
            let plan = FaultCfg { enabled: true, sever_rate: 0.5, ..FaultCfg::default() }
                .plan()
                .unwrap();
            let (serve_only, _) = train_data_parallel_faulted(
                cfg.clone(),
                &planner,
                &batches,
                &dp,
                Some(&plan),
            );
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&base.losses), bits(&none.losses), "{placement:?}: None drifted");
            assert_eq!(
                bits(&base.losses),
                bits(&serve_only.losses),
                "{placement:?}: serve-only plan drifted"
            );
        }
    }

    #[test]
    fn dead_worker_is_rerouted_and_training_still_learns() {
        use crate::runtime::fault::FaultCfg;
        let (cfg, batches) = setup();
        let planner = AccessPlanner::for_engine_cfg(&cfg);
        for placement in [Placement::Replicated, Placement::Plan] {
            let dp = DpCfg {
                workers: 3,
                placement,
                cost: zero_cost(),
                seed: 5,
                quantize_comm: false,
            };
            let plan = FaultCfg {
                enabled: true,
                dead_worker: Some(1),
                dead_round: 3,
                ..FaultCfg::default()
            }
            .plan()
            .unwrap();
            let (rep, _) =
                train_data_parallel_faulted(cfg.clone(), &planner, &batches, &dp, Some(&plan));
            assert_eq!(rep.steps, 16);
            assert!(rep.losses.iter().all(|l| l.is_finite()), "{placement:?}: NaN loss");
            let head = rep.losses[0];
            let tail = rep.losses[rep.losses.len() - 1];
            assert!(tail < head, "{placement:?}: no learning past a dead worker: {head} -> {tail}");
            assert_eq!(plan.event_count("dead"), 1, "{placement:?}: death not logged once");
        }
    }

    #[test]
    fn straggler_exclusion_converges_close_to_full_participation() {
        use crate::runtime::fault::FaultCfg;
        let (cfg, batches) = setup();
        let planner = AccessPlanner::for_engine_cfg(&cfg);
        let dp = DpCfg {
            workers: 3,
            placement: Placement::Replicated,
            cost: zero_cost(),
            seed: 5,
            quantize_comm: false,
        };
        let (full, _) = train_data_parallel_placed(cfg.clone(), &planner, &batches, &dp);
        let plan = FaultCfg {
            enabled: true,
            straggle_rate: 0.3,
            straggle_ms: 0, // decision logic under test, not the sleep
            ..FaultCfg::default()
        }
        .plan()
        .unwrap();
        let (lossy, _) =
            train_data_parallel_faulted(cfg, &planner, &batches, &dp, Some(&plan));
        assert!(plan.event_count("straggle") > 0, "rate 0.3 over 48 draws never fired");
        assert!(lossy.losses.iter().all(|l| l.is_finite()));
        let full_tail = full.losses[full.losses.len() - 1];
        let lossy_tail = lossy.losses[lossy.losses.len() - 1];
        // error-feedback carry keeps the excluded rounds' progress: the
        // trajectory tracks full participation closely, not exactly
        assert!(
            (lossy_tail - full_tail).abs() < 0.1,
            "straggler tail loss {lossy_tail} drifted from full-participation {full_tail}"
        );
        assert!(lossy_tail < lossy.losses[0], "no learning under stragglers");
    }

    #[test]
    fn remainder_spreads_across_leading_workers() {
        let (_, batches) = setup();
        let b = &batches[0]; // 32 samples
        let sizes: Vec<usize> =
            (0..5).map(|w| shard(b, 2, w, 5).batch_size).collect();
        assert_eq!(sizes, vec![7, 7, 6, 6, 6]);
        assert_eq!(sizes.iter().sum::<usize>(), 32);
        // shards tile the batch contiguously
        let mut labels = Vec::new();
        for w in 0..5 {
            labels.extend(shard(b, 2, w, 5).labels);
        }
        assert_eq!(labels, b.labels);
    }
}
