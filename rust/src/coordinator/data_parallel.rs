//! Real multi-worker data-parallel training (paper Fig. 8's ALLReduce arm,
//! executed with actual OS threads rather than the analytic composition of
//! `baselines::multi_gpu`).
//!
//! Every worker owns a full replica (MLPs + Eff-TT cores — small enough to
//! replicate, which is Rec-AD's §V-H scalability argument), consumes its
//! shard of each global batch, and all-reduces the *parameter deltas*
//! after each step: with SGD, averaging post-step parameters from a common
//! starting point is exactly averaging gradients, and it lets us reuse the
//! engine's fused update unchanged.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::allreduce::AllReduce;
use crate::coordinator::engine::{EngineCfg, NativeDlrm, TableSlot};
use crate::coordinator::platform::CostModel;
use crate::data::ctr::Batch;
use crate::util::prng::Rng;

#[derive(Debug)]
pub struct DataParallelReport {
    pub workers: usize,
    pub steps: u64,
    pub wall: Duration,
    pub throughput: f64,
    /// Per-step mean loss (averaged across workers).
    pub losses: Vec<f32>,
}

/// Flatten all trainable parameters into one vector (allreduce payload).
fn flatten(engine: &NativeDlrm, out: &mut Vec<f32>) {
    out.clear();
    for l in engine.bot.iter().chain(&engine.top) {
        out.extend_from_slice(&l.w);
        out.extend_from_slice(&l.b);
    }
    for t in &engine.tables {
        match t {
            TableSlot::Tt(t) => {
                out.extend_from_slice(&t.core1);
                out.extend_from_slice(&t.core2);
                out.extend_from_slice(&t.core3);
            }
            TableSlot::Plain(t) => out.extend_from_slice(&t.weights),
        }
    }
}

/// Write a flat parameter vector back into the engine.
fn unflatten(engine: &mut NativeDlrm, flat: &[f32]) {
    let mut at = 0usize;
    let mut take = |n: usize| -> &[f32] {
        let s = &flat[at..at + n];
        at += n;
        s
    };
    for l in engine.bot.iter_mut().chain(engine.top.iter_mut()) {
        let n = l.w.len();
        l.w.copy_from_slice(take(n));
        let n = l.b.len();
        l.b.copy_from_slice(take(n));
    }
    for t in engine.tables.iter_mut() {
        match t {
            TableSlot::Tt(t) => {
                let n = t.core1.len();
                t.core1.copy_from_slice(take(n));
                let n = t.core2.len();
                t.core2.copy_from_slice(take(n));
                let n = t.core3.len();
                t.core3.copy_from_slice(take(n));
            }
            TableSlot::Plain(t) => {
                let n = t.weights.len();
                t.weights.copy_from_slice(take(n));
            }
        }
    }
    assert_eq!(at, flat.len(), "flat parameter size drift");
}

/// Split a global batch into `n` contiguous shards (last may be larger).
fn shard(batch: &Batch, n_sparse: usize, w: usize, n: usize) -> Batch {
    let per = batch.batch_size / n;
    let lo = w * per;
    let hi = if w + 1 == n { batch.batch_size } else { lo + per };
    let nd = batch.dense.len() / batch.batch_size;
    Batch {
        dense: batch.dense[lo * nd..hi * nd].to_vec(),
        sparse: batch.sparse[lo * n_sparse..hi * n_sparse].to_vec(),
        labels: batch.labels[lo..hi].to_vec(),
        batch_size: hi - lo,
    }
}

/// Train `batches` across `n_workers` replicas with per-step all-reduce.
pub fn train_data_parallel(
    cfg: EngineCfg,
    batches: &[Batch],
    n_workers: usize,
    cost: CostModel,
    seed: u64,
) -> DataParallelReport {
    assert!(n_workers >= 1);
    let n_sparse = cfg.n_tables();
    // identical init across replicas: same seed
    let proto = NativeDlrm::new(cfg.clone(), &mut Rng::new(seed));
    let mut probe = Vec::new();
    flatten(&proto, &mut probe);
    let payload = probe.len();
    let ar = AllReduce::new(n_workers, payload, cost);
    drop(proto);

    let t0 = Instant::now();
    let losses = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_workers)
            .map(|w| {
                let ar: Arc<AllReduce> = Arc::clone(&ar);
                let cfg = cfg.clone();
                scope.spawn(move || {
                    let mut engine = NativeDlrm::new(cfg, &mut Rng::new(seed));
                    let mut flat = vec![0.0f32; payload];
                    let mut my_losses = Vec::with_capacity(batches.len());
                    for batch in batches {
                        let sb = shard(batch, n_sparse, w, n_workers);
                        let loss = engine.train_step(&sb);
                        // average post-step params == average grads (SGD)
                        flatten(&engine, &mut flat);
                        ar.allreduce_mean(&mut flat);
                        unflatten(&mut engine, &flat);
                        my_losses.push(loss);
                    }
                    my_losses
                })
            })
            .collect();
        let all: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // mean loss per step across workers
        (0..batches.len())
            .map(|s| all.iter().map(|l| l[s]).sum::<f32>() / n_workers as f32)
            .collect::<Vec<f32>>()
    });
    let wall = t0.elapsed();
    let samples: u64 = batches.iter().map(|b| b.batch_size as u64).sum();
    DataParallelReport {
        workers: n_workers,
        steps: batches.len() as u64,
        wall,
        throughput: samples as f64 / wall.as_secs_f64(),
        losses,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::ctr::CtrGenerator;
    use crate::data::schema::DatasetSchema;
    use crate::tt::table::EffTtOptions;

    fn setup() -> (EngineCfg, Vec<Batch>) {
        let cfg = EngineCfg {
            dense_dim: 4,
            emb_dim: 8,
            tables: vec![(1500, true), (60, false)],
            tt_rank: 4,
            bot_hidden: vec![16],
            top_hidden: vec![16],
            lr: 0.05,
            tt_opts: EffTtOptions::default(),
            exec: crate::exec::ExecCfg::default(),
        };
        let schema = DatasetSchema {
            name: "dp-test",
            n_dense: 4,
            vocabs: vec![1500, 60],
            emb_dim: 8,
            zipf_s: 1.2,
            ft_rank: 8,
        };
        let mut gen = CtrGenerator::new(schema, 11);
        (cfg, gen.batches(16, 32))
    }

    fn zero_cost() -> CostModel {
        CostModel {
            h2d_bps: 1e18,
            d2d_bps: 1e18,
            transfer_latency: Duration::ZERO,
            ps_row: Duration::ZERO,
            dispatch: Duration::ZERO,
        }
    }

    #[test]
    fn single_worker_equals_plain_training() {
        let (cfg, batches) = setup();
        let dp = train_data_parallel(cfg.clone(), &batches, 1, zero_cost(), 5);
        let mut engine = NativeDlrm::new(cfg, &mut Rng::new(5));
        let direct: Vec<f32> = batches.iter().map(|b| engine.train_step(b)).collect();
        assert_eq!(dp.losses, direct, "1-worker DP must equal plain SGD");
    }

    #[test]
    fn multi_worker_learns_and_stays_synchronized() {
        let (cfg, batches) = setup();
        let dp = train_data_parallel(cfg, &batches, 3, zero_cost(), 5);
        assert_eq!(dp.steps, 16);
        let head = dp.losses[0];
        let tail = dp.losses[dp.losses.len() - 1];
        assert!(tail < head, "no learning under DP: {head} -> {tail}");
    }

    #[test]
    fn flatten_unflatten_roundtrip() {
        let (cfg, _) = setup();
        let a = NativeDlrm::new(cfg.clone(), &mut Rng::new(1));
        let mut flat = Vec::new();
        flatten(&a, &mut flat);
        let mut b = NativeDlrm::new(cfg, &mut Rng::new(2));
        unflatten(&mut b, &flat);
        let mut flat_b = Vec::new();
        flatten(&b, &mut flat_b);
        assert_eq!(flat, flat_b);
    }
}
