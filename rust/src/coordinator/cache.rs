//! GPU-side embedding cache + RAW-conflict synchronizer (paper §IV-B,
//! Fig. 9).
//!
//! The pipeline prefetches batch i+1's embedding rows from host memory
//! while batch i is still training, so a prefetched row may be **stale**:
//! batch i's gradient update to that row happened on the device after the
//! prefetch snapshot left the host (read-after-write hazard).
//!
//! The fix mirrors Fig. 9(b): rows updated on-device are written to the
//! secondary cache (`Emb2`) with a version counter; when a prefetched
//! batch arrives, any row whose cached version is newer than the prefetch
//! snapshot version is patched from the cache instead of being trusted.
//! Lifecycle control (the paper's LC parameter) bounds memory: each
//! cached row has a load-capacity counter, decremented per step, evicted
//! at zero unless re-touched.

use std::collections::HashMap;

/// One embedding row in transit between host and device.
#[derive(Clone, Debug)]
pub struct PrefetchedRow {
    pub row: u64,
    pub data: Vec<f32>,
    /// Host parameter version at snapshot time.
    pub version: u64,
}

/// A prefetched batch (what the PS pushes into the prefetch queue).
pub struct PrefetchBatch {
    pub step: u64,
    /// Per (table, row) payloads.
    pub rows: Vec<(usize, PrefetchedRow)>,
}

struct CacheEntry {
    data: Vec<f32>,
    /// Device-side version (monotonic per update).
    version: u64,
    /// Remaining lifecycle (steps until eviction if untouched).
    lc: u32,
}

/// Per-device embedding cache with RAW synchronization.
pub struct EmbeddingCache {
    entries: HashMap<(usize, u64), CacheEntry>,
    /// LC assigned on (re)touch.
    pub lc_init: u32,
    /// Monotonic device version counter.
    version_clock: u64,
    pub hits: u64,
    pub misses: u64,
    pub raw_conflicts_fixed: u64,
    pub evictions: u64,
}

impl EmbeddingCache {
    pub fn new(lc_init: u32) -> Self {
        EmbeddingCache {
            entries: HashMap::new(),
            lc_init,
            version_clock: 0,
            hits: 0,
            misses: 0,
            raw_conflicts_fixed: 0,
            evictions: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Approximate resident bytes.
    pub fn bytes(&self) -> u64 {
        // lint:allow(D1) u64 sum is commutative — no fp accumulation order
        self.entries
            .values()
            .map(|e| (e.data.len() * 4 + 32) as u64)
            .sum()
    }

    /// Record a device-side update of `row` at `version` (after the
    /// training step producing that version wrote new values).  This is
    /// the write side of the RAW fix: the freshest copy now lives in the
    /// cache (Fig. 9(b) "synchronized with Emb2").  Versions are step
    /// numbers: a row written at step i carries version i+1, and a
    /// prefetch snapshot taken with `k` host-applied steps carries
    /// version k — strictly newer cache entries patch the prefetch.
    pub fn record_update(&mut self, table: usize, row: u64, data: &[f32], version: u64) {
        self.version_clock = self.version_clock.max(version);
        let v = version;
        let lc = self.lc_init;
        let e = self.entries.entry((table, row)).or_insert_with(|| CacheEntry {
            data: Vec::new(),
            version: 0,
            lc,
        });
        e.data.clear();
        e.data.extend_from_slice(data);
        e.version = v;
        e.lc = self.lc_init;
    }

    /// Reconcile a prefetched batch against the cache: any row with a
    /// newer device-side version is patched in place.  Returns how many
    /// rows were stale (RAW conflicts the synchronizer fixed).
    pub fn sync_prefetch(&mut self, batch: &mut PrefetchBatch) -> usize {
        let mut fixed = 0;
        for (table, pr) in batch.rows.iter_mut() {
            match self.entries.get_mut(&(*table, pr.row)) {
                Some(e) if e.version > pr.version => {
                    pr.data.clear();
                    pr.data.extend_from_slice(&e.data);
                    pr.version = e.version;
                    e.lc = self.lc_init; // touch
                    fixed += 1;
                    self.hits += 1;
                }
                Some(e) => {
                    e.lc = self.lc_init; // fresh prefetch confirms residency
                    self.hits += 1;
                }
                None => {
                    self.misses += 1;
                }
            }
        }
        self.raw_conflicts_fixed += fixed as u64;
        fixed
    }

    /// End-of-step lifecycle pass: decrement LC, evict the dead.
    pub fn end_step(&mut self) {
        let before = self.entries.len();
        // lint:allow(D1) per-entry LC decrement is independent of visit order
        self.entries.retain(|_, e| {
            if e.lc > 0 {
                e.lc -= 1;
                true
            } else {
                false
            }
        });
        self.evictions += (before - self.entries.len()) as u64;
    }

    /// Current device version clock (used as the "snapshot version" by
    /// the PS when it builds a prefetch batch from host data).
    pub fn clock(&self) -> u64 {
        self.version_clock
    }

    pub fn get(&self, table: usize, row: u64) -> Option<&[f32]> {
        self.entries.get(&(table, row)).map(|e| e.data.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pf(table: usize, row: u64, val: f32, version: u64) -> (usize, PrefetchedRow) {
        (table, PrefetchedRow { row, data: vec![val; 4], version })
    }

    #[test]
    fn stale_prefetch_gets_patched() {
        let mut c = EmbeddingCache::new(4);
        // device wrote row 7 at version 1
        c.record_update(0, 7, &[9.0; 4], 1);
        // PS snapshot was taken before that write (version 0)
        let mut batch = PrefetchBatch { step: 1, rows: vec![pf(0, 7, 1.0, 0)] };
        let fixed = c.sync_prefetch(&mut batch);
        assert_eq!(fixed, 1);
        assert_eq!(batch.rows[0].1.data, vec![9.0; 4]);
        assert_eq!(c.raw_conflicts_fixed, 1);
    }

    #[test]
    fn fresh_prefetch_untouched() {
        let mut c = EmbeddingCache::new(4);
        c.record_update(0, 7, &[9.0; 4], 1); // version 1
        // PS snapshot taken AFTER the host applied that gradient: the
        // prefetched value already reflects it (version >= cache)
        let mut batch = PrefetchBatch { step: 1, rows: vec![pf(0, 7, 5.0, 1)] };
        let fixed = c.sync_prefetch(&mut batch);
        assert_eq!(fixed, 0);
        assert_eq!(batch.rows[0].1.data, vec![5.0; 4]);
    }

    #[test]
    fn never_serves_stale_rows_property() {
        // Interleave device writes and prefetches; after every sync, the
        // prefetched data must equal the latest device write if one
        // happened after the snapshot.
        let mut c = EmbeddingCache::new(8);
        let mut latest = vec![0.0f32; 4];
        for step in 0..50u64 {
            let snap = c.clock();
            if step % 3 == 0 {
                latest = vec![step as f32; 4];
                c.record_update(0, 42, &latest, snap + 1);
            }
            let mut b = PrefetchBatch {
                step,
                rows: vec![pf(0, 42, -1.0, snap)],
            };
            c.sync_prefetch(&mut b);
            if c.clock() > snap {
                assert_eq!(b.rows[0].1.data, latest, "stale row at step {step}");
            }
            c.end_step();
        }
    }

    #[test]
    fn lifecycle_evicts_untouched() {
        let mut c = EmbeddingCache::new(2);
        c.record_update(0, 1, &[1.0; 4], 1);
        assert_eq!(c.len(), 1);
        c.end_step(); // lc 2 -> 1
        c.end_step(); // lc 1 -> 0
        c.end_step(); // evicted
        assert_eq!(c.len(), 0);
        assert_eq!(c.evictions, 1);
    }

    #[test]
    fn touch_resets_lifecycle() {
        let mut c = EmbeddingCache::new(2);
        c.record_update(0, 1, &[1.0; 4], 1);
        c.end_step();
        // a prefetch touching the row resets its LC
        let mut b = PrefetchBatch { step: 0, rows: vec![pf(0, 1, 0.0, c.clock())] };
        c.sync_prefetch(&mut b);
        c.end_step();
        c.end_step();
        assert_eq!(c.len(), 1, "touched row evicted too early");
    }

    #[test]
    fn bytes_accounting_scales_with_entries() {
        let mut c = EmbeddingCache::new(4);
        let b0 = c.bytes();
        for r in 0..10 {
            c.record_update(0, r, &[0.0; 16], r + 1);
        }
        assert!(c.bytes() > b0 + 10 * 64);
    }
}
