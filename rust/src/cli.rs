//! Command-line interface (clap is unavailable offline): subcommands +
//! `--key value` / `--flag` option parsing with typed accessors.

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug)]
pub struct Cli {
    pub subcommand: String,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse `argv[1..]`: first positional is the subcommand, then
    /// `--key value` pairs and bare `--flag`s.
    pub fn parse(args: &[String]) -> Result<Cli> {
        if args.is_empty() {
            bail!("missing subcommand; try `recad help`");
        }
        let subcommand = args[0].clone();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument '{a}'");
            };
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                options.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Cli { subcommand, options, flags })
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: '{v}' is not an integer")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: '{v}' is not a number")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

pub const USAGE: &str = "\
recad — Rec-AD: TT-compressed DLRM for FDIA detection

USAGE:
  recad <subcommand> [--option value] [--flag]

SUBCOMMANDS:
  train        Train the FDIA detector on synthetic IEEE-118 data
               --config file.toml  --epochs N  --batch N  --scale F
               --workers N  --no-reorder  --no-reuse  --pipeline
               --plan-ahead N (ingest lookahead, 0 = inline planning)
               --online-reorder (refresh the index bijection online)
               --background-reorder (rebuilds on a worker, epoch swap)
               --cache-kb N (L2 tile budget for plan layouts; 0 = off)
               --fuse-tables (fused same-vocab planning sweep)
               --devices N (data-parallel replica workers; 1 = single)
               --placement replicated|plan (multi-device batch routing:
                 plan routes TT prefix groups to their owning worker and
                 ships TT-core gradients as sparse (offset, delta) runs)
               --quantize off|int8|f16 (int8 also compresses the plan-
                 placed gradient exchange: per-run scales + error
                 feedback on the sender)
               --autotune (feedback-tune cache_kb from measured step
                 times and refresh_every from reuse-rate decay; see
                 the [autotune] config section for the knobs)
               --fault-straggle-rate F  --fault-straggle-ms N
                 (chaos: workers miss the all-reduce deadline — weight-0
                 exclusion + error-feedback carry)
               --fault-dead-worker N  --fault-dead-round N
                 (chaos: worker N dies permanently at round N; its shard
                 re-routes to the live workers)
  serve        Stream detection over a held-out sample stream
               --requests N  --threshold F
               --replicas N (detector shards; was --workers pre-redesign)
               --policy round_robin|least_queued|plan_affinity
               --max-batch N  --deadline-us N (micro-batch fill deadline)
               --clients N (closed-loop concurrency; 0 = 2x replicas)
               --arrival-rate F (open-loop Poisson req/s; 0 = closed loop)
               --dispatch-us N (per-call dispatch charge)
               --quantize off|int8|f16 (freeze TT cores into quantized
                 tiles for serving; dequantize-in-microkernel fast path)
               --autotune (per-replica max_batch/deadline_us feedback
                 loop bounded by [autotune] target_p99_us)
               --shed-budget-us N (refuse requests whose queue-delay
                 estimate exceeds N µs: Reply { shed }; 0 = never shed)
               --heartbeat-ms N (supervisor period: dead/hung replicas
                 respawn from the frozen snapshot; 0 = no supervision)
               --hang-ms N (hung-replica threshold for the supervisor)
               --fault-seed N (enable the chaos plan at seed N)
               --fault-kill-replica N  --fault-kill-after N
                 (chaos: replica N panics after serving N requests)
               --fault-stall-rate F  --fault-stall-ms N (chaos: stalls)
               --fault-sever-rate F (chaos: reply channels severed)
               --fault-flood-rate F  --fault-flood-burst N (chaos:
                 junk-request queue floods)
  node         Serve one detector node over TCP (multi-node tier).
               Trains the same seeded detector as `serve`, wraps it in a
               ServeSession and answers length-prefixed binary frames.
               --listen host:port ([net] listen; port 0 = ephemeral)
               --node-id N (ring identity — must equal this node's
                 position in the router's --nodes list)
               --generation N (respawn epoch; chaos kills fire only at
                 generation 0, so respawned nodes survive)
               --threshold F  ([serve] knobs apply per node)
               --fault-kill-node N  --fault-node-kill-after N
                 (chaos: node N drops mid-request after serving N)
  route        Open-loop router driving detector nodes over TCP:
               consistent-hash ring keyed on the plan-affinity snapshot,
               heartbeat eviction, in-flight re-route on node death.
               --nodes host:port,host:port,…  ([net] nodes)
               --requests N  --arrival-rate F (Poisson req/s)
               ([net] vnodes = ring points per node, heartbeat_ms =
                probe cadence, max_outstanding = per-node backpressure)
  gen-data     Generate and summarize the IEEE-118 FDIA dataset
               --normal N  --attack N  --seed N
  runtime      Smoke-run the PJRT artifacts (requires `make artifacts`)
               --artifacts DIR
  report       Print the static Table II / Table IV footprint report
  lint         Determinism & robustness analysis over the crate source
               (rules D1-D6: hash-order iteration, wall-clock reads,
               request-path panics, raw spawns, nondeterministic rng,
               unjustified unsafe; suppress a justified site with
               `// lint:allow(<rule>) <reason>`)
               --deny (exit non-zero on any finding — the CI gate)
               --rule D3 (run a single rule)
               --json (machine-readable findings; schema in README)
               --root DIR (crate root; default ./ or rust/)
               --strict-pragmas (also flag pragmas suppressing nothing)
  help         Show this message
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let c = Cli::parse(&sv(&["train", "--epochs", "5", "--no-reorder"])).unwrap();
        assert_eq!(c.subcommand, "train");
        assert_eq!(c.usize_or("epochs", 1).unwrap(), 5);
        assert!(c.flag("no-reorder"));
        assert!(!c.flag("pipeline"));
    }

    #[test]
    fn rejects_bad_input() {
        assert!(Cli::parse(&sv(&[])).is_err());
        assert!(Cli::parse(&sv(&["train", "positional"])).is_err());
        let c = Cli::parse(&sv(&["train", "--epochs", "abc"])).unwrap();
        assert!(c.usize_or("epochs", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let c = Cli::parse(&sv(&["serve"])).unwrap();
        assert_eq!(c.opt_or("threshold", "0.5"), "0.5");
        assert_eq!(c.f64_or("threshold", 0.5).unwrap(), 0.5);
    }
}
