//! `recad` — the Rec-AD leader binary: train / serve / gen-data /
//! runtime-smoke / report subcommands over the library.

use anyhow::Result;

use recad::analysis;
use recad::cli::{Cli, USAGE};
use recad::config::RecAdConfig;
use recad::coordinator::data_parallel::{DpCfg, Placement};
use recad::coordinator::engine::NativeDlrm;
use recad::coordinator::pipeline::{self, PipelineCfg};
use recad::coordinator::platform::SimPlatform;
use recad::coordinator::trainer;
use recad::data::schema;
use recad::net::{run_open_loop_net, NetClient, NodeServer};
use recad::powersys::dataset::{generate, DatasetCfg, SparseVocab};
use recad::runtime::{Artifacts, DlrmTrainStep, TtLookupExe};
use recad::serve::{run_open_loop, OpenLoopCfg, Policy, ServeSession};
use recad::tt::table::QuantizeMode;
use recad::util::bench::{fmt_bytes, fmt_dur, Table};
use recad::util::prng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let cli = match Cli::parse(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{USAGE}");
            return Err(e);
        }
    };
    match cli.subcommand.as_str() {
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "train" => cmd_train(&cli),
        "serve" => cmd_serve(&cli),
        "node" => cmd_node(&cli),
        "route" => cmd_route(&cli),
        "gen-data" => cmd_gen_data(&cli),
        "runtime" => cmd_runtime(&cli),
        "report" => cmd_report(),
        "lint" => cmd_lint(&cli),
        other => {
            eprintln!("{USAGE}");
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn load_config(cli: &Cli) -> Result<RecAdConfig> {
    let mut cfg = match cli.opt("config") {
        Some(path) => RecAdConfig::load(path)?,
        None => RecAdConfig::default(),
    };
    cfg.epochs = cli.usize_or("epochs", cfg.epochs)?;
    cfg.batch_size = cli.usize_or("batch", cfg.batch_size)?;
    cfg.scale = cli.f64_or("scale", cfg.scale)?;
    cfg.workers = cli.usize_or("workers", cfg.workers)?.max(1);
    cfg.plan_ahead = cli.usize_or("plan-ahead", cfg.plan_ahead)?;
    cfg.cache_kb = cli.usize_or("cache-kb", cfg.cache_kb)?;
    cfg.devices = cli.usize_or("devices", cfg.devices)?.max(1);
    if let Some(p) = cli.opt("placement") {
        cfg.placement = Placement::parse(p)?;
    }
    if let Some(q) = cli.opt("quantize") {
        cfg.quantize = QuantizeMode::parse(q)?;
    }
    if cli.flag("online-reorder") {
        cfg.online_reorder = true;
    }
    if cli.flag("background-reorder") {
        cfg.online_reorder = true;
        cfg.background_reorder = true;
    }
    if cli.flag("fuse-tables") {
        cfg.fuse_tables = true;
    }
    if cli.flag("no-reorder") {
        cfg.reorder = false;
    }
    if cli.flag("no-reuse") {
        cfg.reuse = false;
    }
    if cli.flag("autotune") {
        cfg.autotune.enabled = true;
    }
    // --fault-* chaos knobs: any explicit knob switches injection on
    let mut fault_touched = false;
    if cli.opt("fault-seed").is_some() {
        cfg.fault.seed = cli.usize_or("fault-seed", cfg.fault.seed as usize)? as u64;
        fault_touched = true;
    }
    if cli.opt("fault-kill-replica").is_some() {
        cfg.fault.kill_replica = Some(cli.usize_or("fault-kill-replica", 0)?);
        fault_touched = true;
    }
    if cli.opt("fault-kill-after").is_some() {
        cfg.fault.kill_after = cli.usize_or("fault-kill-after", 0)? as u64;
        fault_touched = true;
    }
    if cli.opt("fault-panic-rate").is_some() {
        cfg.fault.panic_rate = cli.f64_or("fault-panic-rate", 0.0)?;
        fault_touched = true;
    }
    if cli.opt("fault-stall-rate").is_some() {
        cfg.fault.stall_rate = cli.f64_or("fault-stall-rate", 0.0)?;
        fault_touched = true;
    }
    if cli.opt("fault-stall-ms").is_some() {
        cfg.fault.stall_ms = cli.usize_or("fault-stall-ms", 0)? as u64;
        fault_touched = true;
    }
    if cli.opt("fault-sever-rate").is_some() {
        cfg.fault.sever_rate = cli.f64_or("fault-sever-rate", 0.0)?;
        fault_touched = true;
    }
    if cli.opt("fault-flood-rate").is_some() {
        cfg.fault.flood_rate = cli.f64_or("fault-flood-rate", 0.0)?;
        fault_touched = true;
    }
    if cli.opt("fault-flood-burst").is_some() {
        cfg.fault.flood_burst = cli.usize_or("fault-flood-burst", 0)?;
        fault_touched = true;
    }
    if cli.opt("fault-straggle-rate").is_some() {
        cfg.fault.straggle_rate = cli.f64_or("fault-straggle-rate", 0.0)?;
        fault_touched = true;
    }
    if cli.opt("fault-straggle-ms").is_some() {
        cfg.fault.straggle_ms = cli.usize_or("fault-straggle-ms", 0)? as u64;
        fault_touched = true;
    }
    if cli.opt("fault-dead-worker").is_some() {
        cfg.fault.dead_worker = Some(cli.usize_or("fault-dead-worker", 0)?);
        fault_touched = true;
    }
    if cli.opt("fault-dead-round").is_some() {
        cfg.fault.dead_round = cli.usize_or("fault-dead-round", 0)? as u64;
        fault_touched = true;
    }
    if cli.opt("fault-kill-node").is_some() {
        cfg.fault.kill_node = Some(cli.usize_or("fault-kill-node", 0)?);
        fault_touched = true;
    }
    if cli.opt("fault-node-kill-after").is_some() {
        cfg.fault.node_kill_after = cli.usize_or("fault-node-kill-after", 0)? as u64;
        fault_touched = true;
    }
    if fault_touched {
        cfg.fault.enabled = true;
    }
    Ok(cfg)
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    println!("Rec-AD training — config: {cfg:?}");
    let ds = generate(&DatasetCfg {
        n_normal: cli.usize_or("normal", 4000)?,
        n_attack: cli.usize_or("attack", 1000)?,
        vocab: SparseVocab::ieee118(cfg.scale),
        n_profiles: 100,
        noise_std: 0.005,
        seed: cfg.seed,
    });
    println!("dataset: {} samples, BDD tau = {:.4}", ds.samples.len(), ds.bdd_tau);

    if cli.flag("pipeline") {
        // PS-pipeline mode over the small host tables
        if cfg.autotune.enabled {
            eprintln!(
                "warning: --autotune applies to single-device access-layer \
                 training; ignoring it under --pipeline"
            );
        }
        if cfg.devices > 1 {
            eprintln!(
                "warning: --pipeline is single-device; ignoring --devices {} \
                 (and --placement)",
                cfg.devices
            );
        }
        let ecfg = cfg.engine_cfg();
        let mut engine = NativeDlrm::new(ecfg, &mut Rng::new(cfg.seed));
        let host_slots = vec![2usize, 3, 4, 5, 6];
        let host = pipeline::split_to_host(&mut engine, &host_slots, &mut Rng::new(cfg.seed ^ 1));
        let batches: Vec<_> = {
            let mut rng = Rng::new(cfg.seed ^ 2);
            recad::data::batcher::EpochIter::new(&ds.samples, cfg.batch_size, &mut rng).collect()
        };
        let mut pcfg = PipelineCfg::new(SimPlatform::v100(1).cost, host_slots);
        pcfg.lc = cfg.pipeline_lc;
        let (report, mut engine, _) = pipeline::run(engine, host, &batches, &pcfg);
        println!(
            "pipeline: {} steps, {:.0} samples/s, RAW fixed {}, cache hits {}",
            report.steps, report.throughput, report.raw_fixed, report.cache_hits
        );
        let eval = trainer::evaluate_on(&mut engine, ds.split(0.8).1);
        print_eval(&eval);
    } else if cfg.devices > 1 {
        // multi-device data-parallel training ([train] devices/placement).
        // The DP driver plans inline per worker (identity planner): the
        // [access] ingest options do not apply — say so instead of
        // silently training a different configuration than requested.
        let access = cfg.access_cfg();
        if cfg.autotune.enabled {
            eprintln!(
                "warning: --autotune tunes the access-layer cache/reorder \
                 loops; multi-device training (--devices {}) plans inline \
                 per worker, so it is ignored",
                cfg.devices
            );
        }
        if access.online_reorder
            || access.background_reorder
            || access.fuse_tables
            || access.plan_ahead != recad::access::AccessCfg::default().plan_ahead
            || access.cache_kb != recad::access::AccessCfg::default().cache_kb
        {
            eprintln!(
                "warning: [access] options (plan-ahead/online-reorder/\
                 background-reorder/cache-kb/fuse-tables) are ignored by \
                 multi-device training (--devices {}); they apply to \
                 single-device runs only",
                cfg.devices
            );
        }
        // each device is already a thread: pin replicas to one intra-step
        // exec worker so devices x workers threads never oversubscribe
        // (the same hazard ServeSession::start pins replicas for)
        if cfg.workers > 1 {
            eprintln!(
                "note: --devices {} pins each replica to 1 intra-step worker \
                 (--workers {} would run devices x workers threads)",
                cfg.devices, cfg.workers
            );
        }
        let mut ecfg = cfg.engine_cfg();
        ecfg.exec = recad::exec::ExecCfg::serial();
        // --quantize int8 under plan placement compresses the gradient
        // exchange; f16 has no wire format (serving-only) — say so.
        let quantize_comm = match cfg.quantize {
            QuantizeMode::Int8 => cfg.placement == Placement::Plan,
            QuantizeMode::F16 => {
                eprintln!(
                    "warning: --quantize f16 is serving-only; training \
                     exchanges stay f32 (use int8 for quantized comm)"
                );
                false
            }
            QuantizeMode::Off => false,
        };
        let dp = DpCfg {
            workers: cfg.devices,
            placement: cfg.placement,
            cost: SimPlatform::v100(cfg.devices).cost,
            seed: cfg.seed,
            quantize_comm,
        };
        let fault_plan = cfg.fault.plan();
        let (report, _engine, eval) = trainer::train_ieee118_dp_faulted(
            ecfg,
            &ds,
            cfg.epochs,
            cfg.batch_size,
            &dp,
            fault_plan.as_ref(),
        );
        println!(
            "data-parallel [{}] x{}: {} steps in {} ({:.0} samples/s, \
             all-reduce payload {})",
            report.placement.as_str(),
            report.workers,
            report.steps,
            fmt_dur(report.wall.as_secs_f64()),
            report.throughput,
            fmt_bytes(report.payload_bytes),
        );
        if let Some(f) = &fault_plan {
            println!(
                "chaos [seed {}]: {} straggler exclusion(s), {} dead-worker event(s)",
                f.cfg().seed,
                f.event_count("straggle"),
                f.event_count("dead"),
            );
        }
        print_eval(&eval);
    } else {
        let access = cfg.access_cfg();
        let (report, _, planner) = trainer::train_ieee118_auto(
            cfg.engine_cfg(),
            &access,
            &cfg.autotune,
            &ds,
            cfg.epochs,
            cfg.batch_size,
            cfg.seed,
        );
        if let Some(tuner) = planner.cache_tuner() {
            println!(
                "autotune[cache]: committed {} (ladder {:?}, {} reprobe(s))",
                tuner
                    .committed_kb()
                    .map(|kb| format!("{kb} KiB"))
                    .unwrap_or_else(|| "nothing yet".into()),
                cfg.autotune.cache_ladder,
                tuner.reprobes,
            );
        }
        if cfg.autotune.reorder_on() {
            for t in 0..planner.num_tables() {
                if let Some(every) = planner.online_refresh_every(t) {
                    println!("autotune[reorder]: table {t} refresh_every -> {every}");
                }
            }
        }
        println!(
            "trained {} steps in {} ({:.0} samples/s; ingest plan-ahead {}{}{}; \
             max ingest plan stall {})",
            report.steps,
            fmt_dur(report.wall.as_secs_f64()),
            report.samples_per_sec,
            access.plan_ahead,
            if access.background_reorder {
                ", background reorder"
            } else if access.online_reorder {
                ", online reorder"
            } else {
                ""
            },
            if access.fuse_tables { ", fused plans" } else { "" },
            fmt_dur(report.plan_stall_max_s)
        );
        let show = report.loss_curve.len().min(10);
        let stride = (report.loss_curve.len() / show).max(1);
        println!("loss curve (every {stride} steps):");
        for (i, l) in report.loss_curve.iter().step_by(stride).enumerate() {
            println!("  step {:>5}  loss {:.4}", i * stride, l);
        }
        print_eval(&report.eval);
    }
    Ok(())
}

fn print_eval(eval: &recad::metrics::ClassifyReport) {
    println!(
        "eval: accuracy {:.1}%  recall {:.1}%  precision {:.1}%  F1 {:.1}%",
        eval.accuracy * 100.0,
        eval.recall * 100.0,
        eval.precision * 100.0,
        eval.f1 * 100.0
    );
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    let requests = cli.usize_or("requests", 500)?;
    let threshold = cli.f64_or("threshold", 0.5)? as f32;
    // [serve] section + CLI overrides.  Replica count is --replicas now;
    // --workers only sets TRAINING workers (the old overload routed it
    // into shard count).
    let mut scfg = cfg.serve;
    scfg.replicas = cli.usize_or("replicas", scfg.replicas)?.max(1);
    scfg.max_batch = cli.usize_or("max-batch", scfg.max_batch)?.max(1);
    if let Some(p) = cli.opt("policy") {
        scfg.policy = Policy::parse(p)?;
    }
    scfg.deadline_us = cli.usize_or("deadline-us", scfg.deadline_us as usize)? as u64;
    scfg.clients = cli.usize_or("clients", scfg.clients)?;
    scfg.arrival_rate = cli.f64_or("arrival-rate", scfg.arrival_rate)?;
    scfg.dispatch_us = cli.usize_or("dispatch-us", scfg.dispatch_us as usize)? as u64;
    scfg.shed_budget_us = cli.usize_or("shed-budget-us", scfg.shed_budget_us as usize)? as u64;
    scfg.heartbeat_ms = cli.usize_or("heartbeat-ms", scfg.heartbeat_ms as usize)? as u64;
    scfg.hang_ms = cli.usize_or("hang-ms", scfg.hang_ms as usize)? as u64;
    let fault_plan = cfg.fault.plan();
    if fault_plan.is_some()
        && scfg.heartbeat_ms == 0
        && (cfg.fault.kill_replica.is_some() || cfg.fault.panic_rate > 0.0)
    {
        eprintln!(
            "warning: replica kill/panic faults are enabled without a \
             supervisor (--heartbeat-ms 0): dead replicas stay dead and \
             their queued requests time out as dropped"
        );
    }

    let ds = generate(&DatasetCfg {
        n_normal: 2000,
        n_attack: 500,
        vocab: SparseVocab::ieee118(cfg.scale),
        n_profiles: 100,
        noise_std: 0.005,
        seed: cfg.seed,
    });
    println!("training detector before serving…");
    // Serve honors the [access] policy end to end: the session threads
    // the SAME planner (bijections + layout knobs) the model trained
    // under into every replica.
    let access = cfg.access_cfg();
    let (report, engine, planner) = trainer::train_ieee118_auto(
        cfg.engine_cfg(),
        &access,
        &cfg.autotune,
        &ds,
        2,
        64,
        cfg.seed,
    );
    print_eval(&report.eval);
    // report the footprint actually served: frozen tiles when quantizing
    let model_bytes = if cfg.quantize != QuantizeMode::Off {
        let mut frozen = engine.clone();
        frozen.freeze_quantized(cfg.quantize);
        println!(
            "serving with {} quantized TT cores ({} vs {} f32)",
            cfg.quantize.as_str(),
            fmt_bytes(frozen.model_bytes()),
            fmt_bytes(engine.model_bytes()),
        );
        frozen.model_bytes()
    } else {
        engine.model_bytes()
    };
    let session = ServeSession::from_trained(engine, planner)
        .threshold(threshold)
        .with_cfg(&scfg)
        .quantize(cfg.quantize)
        .autotune(&cfg.autotune)
        .fault(fault_plan.clone());
    if cfg.autotune.serve_on() {
        println!(
            "autotune[serve]: replicas adapt max_batch/deadline toward \
             p99 <= {} us (cap {})",
            cfg.autotune.target_p99_us, cfg.autotune.max_batch_cap
        );
    }
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    if scfg.arrival_rate > 0.0 {
        // open loop: Poisson arrivals, attack-window accounting
        let server = session.start();
        let ol = run_open_loop(
            server,
            stream,
            &OpenLoopCfg { rate_per_sec: scfg.arrival_rate, seed: cfg.seed ^ 0x0417 },
        );
        println!(
            "open loop [{}]: {}/{} served on {} replica(s) at {:.0}/s offered \
             ({:.0}/s achieved)",
            ol.policy, ol.served, ol.offered, ol.replicas, ol.offered_rate, ol.achieved_rate
        );
        println!(
            "attack window p50 {} / p99 {} / max {}  (queue p99 {} + service p99 {})",
            fmt_dur(ol.p50_window.as_secs_f64()),
            fmt_dur(ol.p99_window.as_secs_f64()),
            fmt_dur(ol.max_window.as_secs_f64()),
            fmt_dur(ol.p99_queue_delay.as_secs_f64()),
            fmt_dur(ol.p99_service.as_secs_f64()),
        );
        if ol.shed > 0 || ol.dropped > 0 || ol.respawns > 0 {
            println!(
                "fault tolerance: {} shed, {} dropped, {} respawn(s); \
                 post-recovery tail p99 {}",
                ol.shed,
                ol.dropped,
                ol.respawns,
                fmt_dur(ol.tail_p99_window.as_secs_f64()),
            );
        }
        if let Some(f) = &fault_plan {
            println!(
                "chaos [seed {}]: {} panic(s), {} stall(s), {} sever(s), \
                 {} flood(s), {} respawn(s)",
                f.cfg().seed,
                f.event_count("panic"),
                f.event_count("stall"),
                f.event_count("sever"),
                f.event_count("flood"),
                f.event_count("respawn"),
            );
        }
    } else {
        let server = session.start();
        let sr = server.run_stream_concurrent(stream, model_bytes, scfg.effective_clients());
        println!(
            "served {} stream requests ({} lifetime) on {} replica(s) via {}: \
             {:.1} TPS, mean latency {}, p99 {}, model {}",
            sr.served,
            sr.lifetime_served,
            sr.replicas,
            sr.policy,
            sr.tps,
            fmt_dur(sr.mean_latency.as_secs_f64()),
            fmt_dur(sr.p99_latency.as_secs_f64()),
            fmt_bytes(sr.model_bytes)
        );
    }
    Ok(())
}

fn cmd_node(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    let id = cli.usize_or("node-id", 0)? as u64;
    let generation = cli.usize_or("generation", 0)? as u64;
    let listen = cli.opt_or("listen", &cfg.net.listen).to_string();
    let threshold = cli.f64_or("threshold", 0.5)? as f32;
    // every node trains the SAME seeded detector the router (and the
    // other nodes) train: same cfg + same seed => bit-identical weights,
    // so verdicts are node-independent and the ring can move keys freely
    let ds = generate(&DatasetCfg {
        n_normal: 2000,
        n_attack: 500,
        vocab: SparseVocab::ieee118(cfg.scale),
        n_profiles: 100,
        noise_std: 0.005,
        seed: cfg.seed,
    });
    println!("node {id}: training detector before listening…");
    let access = cfg.access_cfg();
    let (report, engine, planner) = trainer::train_ieee118_auto(
        cfg.engine_cfg(),
        &access,
        &cfg.autotune,
        &ds,
        2,
        64,
        cfg.seed,
    );
    print_eval(&report.eval);
    let fault_plan = cfg.fault.plan();
    let session = ServeSession::from_trained(engine, planner)
        .threshold(threshold)
        .with_cfg(&cfg.serve)
        .quantize(cfg.quantize)
        .fault(fault_plan.clone());
    let node = NodeServer::spawn(id, generation, session, &listen, fault_plan)?;
    println!(
        "node {} (generation {}) listening on {}",
        node.id(),
        node.generation(),
        node.addr()
    );
    while !node.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let served = node.shutdown();
    println!("node {id} stopped after serving {served} request(s)");
    Ok(())
}

fn cmd_route(cli: &Cli) -> Result<()> {
    let cfg = load_config(cli)?;
    let requests = cli.usize_or("requests", 500)?;
    let default_rate = if cfg.serve.arrival_rate > 0.0 { cfg.serve.arrival_rate } else { 2000.0 };
    let rate = cli.f64_or("arrival-rate", default_rate)?;
    let nodes: Vec<String> = match cli.opt("nodes") {
        Some(s) => s
            .split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect(),
        None => cfg.net.node_list(),
    };
    anyhow::ensure!(
        !nodes.is_empty(),
        "no nodes to route to: pass --nodes host:port,… or set [net] nodes"
    );
    // the affinity snapshot the ring keys on comes from the same seeded
    // training run the nodes performed
    let ds = generate(&DatasetCfg {
        n_normal: 2000,
        n_attack: 500,
        vocab: SparseVocab::ieee118(cfg.scale),
        n_profiles: 100,
        noise_std: 0.005,
        seed: cfg.seed,
    });
    println!("router: deriving the plan-affinity snapshot (same training run as the nodes)…");
    let access = cfg.access_cfg();
    let (_report, _engine, planner) = trainer::train_ieee118_auto(
        cfg.engine_cfg(),
        &access,
        &cfg.autotune,
        &ds,
        2,
        64,
        cfg.seed,
    );
    let affinity = planner.affinity_map();
    let mut client =
        NetClient::connect(affinity, &nodes, cfg.net.vnodes, cfg.net.max_outstanding)?.timeouts(
            std::time::Duration::from_millis(cfg.net.heartbeat_ms.max(1)),
            std::time::Duration::from_millis(500),
        );
    let stream = &ds.samples[..requests.min(ds.samples.len())];
    println!(
        "router: {} node(s), ring epoch {}, open loop at {:.0} req/s over {} requests",
        nodes.len(),
        client.router().epoch(),
        rate,
        stream.len()
    );
    let nl = run_open_loop_net(
        &mut client,
        stream,
        &OpenLoopCfg { rate_per_sec: rate, seed: cfg.seed ^ 0x0417 },
        None,
    );
    client.close();
    let ol = &nl.report;
    println!(
        "open loop [{}]: {}/{} served on {} node(s) at {:.0}/s offered ({:.0}/s achieved)",
        ol.policy, ol.served, ol.offered, nl.nodes, ol.offered_rate, ol.achieved_rate
    );
    println!(
        "attack window p50 {} / p99 {} / max {}  (queue p99 {} + service p99 {})",
        fmt_dur(ol.p50_window.as_secs_f64()),
        fmt_dur(ol.p99_window.as_secs_f64()),
        fmt_dur(ol.max_window.as_secs_f64()),
        fmt_dur(ol.p99_queue_delay.as_secs_f64()),
        fmt_dur(ol.p99_service.as_secs_f64()),
    );
    println!(
        "ring: epoch {}, {} eviction(s), {} rejoin(s); {} shed, {} dropped, {} undeliverable",
        nl.ring_epoch, nl.evictions, nl.rejoins, ol.shed, ol.dropped, client.undeliverable
    );
    Ok(())
}

fn cmd_gen_data(cli: &Cli) -> Result<()> {
    let ds = generate(&DatasetCfg {
        n_normal: cli.usize_or("normal", 20_000)?,
        n_attack: cli.usize_or("attack", 4_800)?,
        vocab: SparseVocab::ieee118(1.0 / 2000.0),
        n_profiles: 200,
        noise_std: 0.005,
        seed: cli.usize_or("seed", 0x5EED)? as u64,
    });
    let attacked = ds.samples.iter().filter(|s| s.label > 0.5).count();
    println!(
        "IEEE118 FDIA dataset: {} samples ({} attacked), BDD tau {:.4}",
        ds.samples.len(),
        attacked,
        ds.bdd_tau
    );
    Ok(())
}

fn cmd_runtime(cli: &Cli) -> Result<()> {
    let dir = cli.opt_or("artifacts", "artifacts");
    println!("loading + compiling artifacts from {dir}/ …");
    let arts = Artifacts::load(dir)?;
    println!(
        "meta: dense={} tables={} train_batch={} params={}",
        arts.meta.dense_dim,
        arts.meta.num_tables,
        arts.meta.train_batch,
        arts.meta.params.len()
    );
    // one train step on random data
    let m = arts.meta.clone();
    let mut rng = Rng::new(1);
    let mut dense = vec![0f32; m.train_batch * m.dense_dim];
    rng.fill_normal(&mut dense, 0.0, 1.0);
    let idx: Vec<i32> = (0..m.train_batch * m.num_tables)
        .map(|i| (rng.below(m.table_rows[i % m.num_tables])) as i32)
        .collect();
    let labels: Vec<f32> = (0..m.train_batch)
        .map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 })
        .collect();
    let mut step = DlrmTrainStep::new(&arts)?;
    let l0 = step.step(&dense, &idx, &labels)?;
    let l1 = step.step(&dense, &idx, &labels)?;
    println!("train_step loss: {l0:.4} -> {l1:.4} (same batch; must descend)");
    anyhow::ensure!(l1 < l0, "loss did not descend on repeated batch");

    // tt_lookup artifact smoke
    let spec = recad::tt::shapes::TtShapes::plan(m.lookup_rows, m.emb_dim, m.lookup_rank);
    let tbl = recad::tt::table::EffTtTable::new(
        spec,
        recad::tt::table::EffTtOptions::default(),
        &mut rng,
    );
    let (d1, d2, d3) = tbl.to_jax_cores();
    let r = m.lookup_rank;
    let idx2: Vec<i32> = (0..m.lookup_batch * m.lookup_bag)
        .map(|_| rng.below(m.lookup_rows) as i32)
        .collect();
    let lookup = TtLookupExe::new(&arts);
    let out = lookup.run(
        (&d1, &[spec.m[0] as usize, spec.n[0], r]),
        (&d2, &[r, spec.m[1] as usize, spec.n[1], r]),
        (&d3, &[r, spec.m[2] as usize, spec.n[2]]),
        &idx2,
    )?;
    println!("tt_lookup artifact OK: {} outputs", out.len());
    println!("runtime smoke PASSED");
    Ok(())
}

fn cmd_report() -> Result<()> {
    let mut t2 = Table::new(
        "Table II — dataset schemas",
        &["Dataset", "Dense", "Sparse", "Rows", "Dim", "Plain size"],
    );
    let mut t4 = Table::new(
        "Table IV — embedding footprint (plain vs Eff-TT)",
        &["Dataset", "DLRM", "Rec-AD", "Compression", "Paper"],
    );
    let paper = [6.22, 74.19, 7.29, 5.33];
    for (s, p) in schema::all_schemas().iter().zip(paper) {
        t2.row(&[
            s.name.to_string(),
            s.n_dense.to_string(),
            s.n_sparse().to_string(),
            format!("{:.1}M", s.total_rows() as f64 / 1e6),
            s.emb_dim.to_string(),
            fmt_bytes(s.plain_bytes()),
        ]);
        let tt = s.tt_bytes(s.ft_rank, 1_000_000);
        t4.row(&[
            s.name.to_string(),
            fmt_bytes(s.plain_bytes()),
            fmt_bytes(tt),
            format!("{:.2}x", s.compression_ratio(s.ft_rank, 1_000_000)),
            format!("{p:.2}x"),
        ]);
    }
    t2.print();
    t4.print();
    Ok(())
}

/// `recad lint [--deny] [--rule <id>] [--json] [--root DIR]
/// [--strict-pragmas]` — the determinism & robustness pass over this
/// crate's own source (see `analysis/`).
fn cmd_lint(cli: &Cli) -> Result<()> {
    let cfg = match cli.opt("config") {
        Some(path) => RecAdConfig::load(path)?,
        None => RecAdConfig::default(),
    };
    let mut lint = cfg.lint.clone();
    if cli.flag("strict-pragmas") {
        lint.strict_pragmas = true;
    }
    // default root: the crate dir when invoked from it, else `rust/`
    // when invoked from the repo root
    let root = match cli.opt("root") {
        Some(r) => std::path::PathBuf::from(r),
        None if std::path::Path::new("src").is_dir() => std::path::PathBuf::from("."),
        None => std::path::PathBuf::from("rust"),
    };
    let run = analysis::run_lint(&root, &lint, cli.opt("rule"))?;
    if cli.flag("json") {
        println!("{}", analysis::report::to_json(&run));
    } else {
        print!("{}", analysis::report::human(&run));
    }
    if cli.flag("deny") && !run.clean() {
        anyhow::bail!("lint --deny: {} finding(s)", run.findings.len());
    }
    Ok(())
}
