//! Streaming inference server: replica worker threads consume per-replica
//! request queues and answer with verdicts.  Which replica serves a
//! request is decided by a pluggable [`RoutePolicy`] (`serve::router`) —
//! round-robin, least-queued, or plan-affinity shard routing — and
//! replicas are clones of one trained detector, so verdicts are bitwise
//! independent of the policy (pinned by `tests/serve_equivalence.rs`).
//!
//! **Micro-batching** (`max_batch > 1`): a replica drains whatever is
//! queued up to the cap; with a non-zero `deadline` it additionally waits
//! up to that long for the batch to fill — the standard serving-router
//! latency/throughput trade-off.  Batching never changes scores.
//!
//! **Fault tolerance**: replica queues are shared deques (not channels),
//! so a panicking worker's queued — and even picked-but-unserved —
//! requests survive it: a drop guard pushes the in-flight batch back and
//! the supervisor thread (enabled by [`GuardCfg::heartbeat`] > 0)
//! respawns the replica from a frozen detector snapshot under a bumped
//! epoch, with the stale incarnation (if merely hung, not dead) retiring
//! itself at its next pickup.  Liveness bits on [`QueueDepths`] steer the
//! route policies away from dead replicas in the interim.  Router-side
//! **load shedding** ([`GuardCfg::shed_budget`]) answers immediately with
//! `Reply { shed: true }` once the queue-delay estimate (EWMA service
//! time × queue depth) exceeds the configured p99 attack-window budget,
//! so overload degrades to bounded-latency partial service instead of
//! unbounded queueing.  All of it is fed by the deterministic
//! [`FaultPlan`](crate::runtime::fault::FaultPlan) chaos harness in
//! tests/benches; with no plan and no supervisor the hot path is the
//! pre-fault-layer code, bit-identical (pinned by
//! `tests/fault_equivalence.rs`).
//!
//! **Accounting**: every [`Reply`] carries the queue-delay / service-time
//! split (enqueue → pickup vs pickup → verdict), which is what the
//! open-loop generator (`serve::load`) needs to attribute the attack
//! window.  [`ServeReport`] counts the driven stream only; requests
//! served before `run_stream*` (e.g. warm-up `infer` calls) appear under
//! `lifetime_served` instead of inflating the stream TPS.
//!
//! Constructing a server by hand is the low-level path — prefer the
//! [`ServeSession`](crate::serve::ServeSession) builder, which threads
//! the trained planner, policy, replica count, deadlines and fault knobs
//! end to end.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use crate::coordinator::platform::SimPlatform;
use crate::powersys::dataset::Sample;
use crate::runtime::autotune::{ServeBatchTuner, ServeTuneCfg};
use crate::runtime::fault::FaultPlan;
use crate::serve::detector::Detector;
use crate::serve::router::{QueueDepths, RoundRobin, RoutePolicy};
use crate::util::clock::Clock;
use crate::util::stats::LatencyHist;
use crate::util::sync::{lock_recover, wait_timeout_recover};

/// Sentinel sequence number for fault-injected flood junk: never severed,
/// and its reply channel is born dead.
const FLOOD_SEQ: u64 = u64::MAX;

/// One in-flight request.
struct Request {
    sample: Sample,
    /// Enqueue timestamp on the server's [`Clock`], in seconds.
    enqueued: f64,
    reply: mpsc::Sender<Reply>,
    /// Global submit sequence (fault-plan key for reply-sever decisions).
    seq: u64,
}

/// One answered request.
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    pub prob: f32,
    /// End-to-end latency: enqueue → verdict delivered.
    pub latency: Duration,
    /// Enqueue → batch pickup: router queueing plus any micro-batch
    /// deadline wait.  For a shed reply: the queue-delay estimate that
    /// tripped the budget.
    pub queue_delay: Duration,
    /// True when the router refused the request under overload instead
    /// of scoring it (`prob` is meaningless).  Shed replies arrive
    /// immediately — bounded-latency partial service.
    pub shed: bool,
}

impl Reply {
    /// Pickup → verdict: dispatch charge + model compute.
    pub fn service_time(&self) -> Duration {
        self.latency.saturating_sub(self.queue_delay)
    }
}

/// Supervision / degradation knobs.  The default (`heartbeat` and
/// `shed_budget` both zero) runs no supervisor thread and never sheds —
/// the exact pre-fault-layer server.
#[derive(Clone, Copy, Debug)]
pub struct GuardCfg {
    /// Shed a request when its routed replica's queue-delay estimate
    /// exceeds this budget (the p99 attack-window target).  Zero = never
    /// shed.
    pub shed_budget: Duration,
    /// Supervisor polling period; zero = no supervisor (and therefore no
    /// respawns and no frozen-detector snapshot held).
    pub heartbeat: Duration,
    /// A live replica whose queue is non-empty but whose heartbeat
    /// counter has not moved for this long is declared hung and
    /// respawned over.
    pub hang: Duration,
}

impl Default for GuardCfg {
    fn default() -> GuardCfg {
        GuardCfg {
            shed_budget: Duration::ZERO,
            heartbeat: Duration::ZERO,
            hang: Duration::from_millis(200),
        }
    }
}

/// The static per-replica knobs (shared by all incarnations).
struct SpawnKnobs {
    max_batch: usize,
    deadline: Duration,
    dispatch: Duration,
    autotune: Option<ServeTuneCfg>,
}

/// One replica's request queue: a deque under a mutex (NOT an mpsc
/// channel) so queued requests outlive a dead worker and are simply
/// picked up by its respawned incarnation.
struct ReplicaQueue {
    q: Mutex<VecDeque<Request>>,
    cv: Condvar,
}

/// State shared by the dispatch side, every replica incarnation, and the
/// supervisor.
struct ServerCore {
    queues: Vec<ReplicaQueue>,
    depths: QueueDepths,
    /// Respawn epoch per replica: bumped by the supervisor; a worker
    /// whose epoch is stale retires at its next pickup.
    epochs: Vec<AtomicU64>,
    /// False once shutdown begins: workers drain their queue, then exit.
    open: AtomicBool,
    served: AtomicU64,
    shed: AtomicU64,
    hist: Mutex<LatencyHist>,
    knobs: SpawnKnobs,
    guard: GuardCfg,
    /// Timestamp source for every enqueue/pickup/verdict split.  Real in
    /// production; a manual clock makes the latency accounting (and the
    /// hang detector) wall-clock-free under test.
    clock: Clock,
    /// EWMA of per-request service nanos (α = 1/8) — the shedding
    /// estimator's cost model.
    svc_ewma_ns: AtomicU64,
    fault: Option<Arc<FaultPlan>>,
    respawns: AtomicU64,
    /// Frozen detector snapshot the supervisor respawns from; `None`
    /// when unsupervised (no extra clone held).
    proto: Mutex<Option<Detector>>,
    handles: Mutex<Vec<thread::JoinHandle<()>>>,
    seq: AtomicU64,
}

impl ServerCore {
    fn epoch_of(&self, id: usize) -> u64 {
        self.epochs[id].load(Ordering::Acquire)
    }

    fn note_service(&self, service: Duration, batch: usize) {
        let per = (service.as_nanos() as u64) / batch.max(1) as u64;
        let prev = self.svc_ewma_ns.load(Ordering::Relaxed);
        let next = if prev == 0 { per } else { prev - prev / 8 + per / 8 };
        self.svc_ewma_ns.store(next, Ordering::Relaxed);
    }

    /// Expected wait for a request routed to `shard` right now.
    fn queue_delay_estimate(&self, shard: usize) -> Duration {
        let per = self.svc_ewma_ns.load(Ordering::Relaxed);
        Duration::from_nanos(per.saturating_mul(self.depths.depth(shard) as u64))
    }
}

/// Marks the replica dead when its worker unwinds — unless the epoch has
/// already moved on (a respawned-over incarnation must not smear the
/// fresh one's liveness bit).
struct AliveGuard {
    core: Arc<ServerCore>,
    id: usize,
    epoch: u64,
}

impl Drop for AliveGuard {
    fn drop(&mut self) {
        if self.core.epoch_of(self.id) == self.epoch {
            self.core.depths.set_alive(self.id, false);
        }
    }
}

/// The picked-but-unserved batch: on panic unwind the requests go back
/// to the FRONT of the queue (original order) for the respawned
/// incarnation — an accepted request is never silently dropped.
struct PendingBatch {
    reqs: Vec<Request>,
    core: Arc<ServerCore>,
    id: usize,
}

impl Drop for PendingBatch {
    fn drop(&mut self) {
        if self.reqs.is_empty() {
            return;
        }
        let q = &self.core.queues[self.id];
        {
            // recover, don't unwrap: this drop guard runs precisely while
            // a panic unwinds, when the queue mutex may be poisoned
            let mut guard = lock_recover(&q.q);
            for r in self.reqs.drain(..).rev() {
                guard.push_front(r);
            }
        }
        q.cv.notify_all();
    }
}

pub struct StreamingServer {
    core: Arc<ServerCore>,
    policy: Arc<dyn RoutePolicy>,
    supervisor: Option<thread::JoinHandle<()>>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    /// Requests served by THIS `run_stream*` call (stream-only).
    pub served: u64,
    /// Requests served over the replicas' whole lifetime — includes any
    /// `infer`/`submit` traffic before the stream.  (The pre-redesign
    /// report conflated this with `served`, inflating `tps`.)
    pub lifetime_served: u64,
    pub wall: Duration,
    /// Stream-only throughput: `served / wall`.
    pub tps: f64,
    /// Stream-only latency stats, recorded at the closed-loop clients.
    pub mean_latency: Duration,
    pub p99_latency: Duration,
    /// Peak device memory ≈ model bytes + activation slack.
    pub model_bytes: u64,
    /// Detector replicas that served the stream.
    pub replicas: usize,
    /// Route policy that dispatched the stream.
    pub policy: &'static str,
}

impl ServeReport {
    /// Serialize for cross-node aggregation (durations as integer
    /// nanoseconds, exact below 2^53).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        let ns = |d: Duration| Json::Num(d.as_nanos() as f64);
        m.insert("served".into(), Json::Num(self.served as f64));
        m.insert("lifetime_served".into(), Json::Num(self.lifetime_served as f64));
        m.insert("wall_ns".into(), ns(self.wall));
        m.insert("tps".into(), Json::Num(self.tps));
        m.insert("mean_latency_ns".into(), ns(self.mean_latency));
        m.insert("p99_latency_ns".into(), ns(self.p99_latency));
        m.insert("model_bytes".into(), Json::Num(self.model_bytes as f64));
        m.insert("replicas".into(), Json::Num(self.replicas as f64));
        m.insert("policy".into(), Json::Str(self.policy.to_string()));
        Json::Obj(m)
    }

    /// Parse a report serialized by [`to_json`](Self::to_json).
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<ServeReport> {
        use crate::util::json::Json;
        use anyhow::Context;
        let u = |k: &str| j.get(k).and_then(Json::as_u64).context(format!("missing {k}"));
        Ok(ServeReport {
            served: u("served")?,
            lifetime_served: u("lifetime_served")?,
            wall: Duration::from_nanos(u("wall_ns")?),
            tps: j.get("tps").and_then(Json::as_f64).context("missing tps")?,
            mean_latency: Duration::from_nanos(u("mean_latency_ns")?),
            p99_latency: Duration::from_nanos(u("p99_latency_ns")?),
            model_bytes: u("model_bytes")?,
            replicas: j.get("replicas").and_then(Json::as_usize).context("missing replicas")?,
            policy: super::router::policy_static(
                j.get("policy").and_then(Json::as_str).context("missing policy")?,
            ),
        })
    }
}

/// One replica incarnation's serve loop.  `my_epoch` retires it once the
/// supervisor has respawned over it.
fn run_replica(core: Arc<ServerCore>, id: usize, my_epoch: u64, mut detector: Detector) {
    let mut tuner = core.knobs.autotune.map(|c| {
        ServeBatchTuner::new(c, core.knobs.max_batch, core.knobs.deadline, core.clock.clone())
    });
    let knobs = tuner.as_ref().map(|t| t.knobs());
    let _alive = AliveGuard { core: Arc::clone(&core), id, epoch: my_epoch };
    let mut served_here: u64 = 0;
    let mut round: u64 = 0;
    loop {
        let mut pending = PendingBatch {
            reqs: Vec::new(),
            core: Arc::clone(&core),
            id,
        };
        {
            let rq = &core.queues[id];
            let mut q = lock_recover(&rq.q);
            // blocking pickup of the first request
            loop {
                if core.epoch_of(id) != my_epoch {
                    return; // respawned over: retire without serving
                }
                if let Some(r) = q.pop_front() {
                    pending.reqs.push(r);
                    break;
                }
                if !core.open.load(Ordering::Acquire) {
                    return; // queue drained and server closed
                }
                let (g, _) = wait_timeout_recover(&rq.cv, q, Duration::from_millis(25));
                q = g;
            }
            let (max_batch, deadline) = match &knobs {
                Some(k) => (k.max_batch(), k.deadline()),
                None => (core.knobs.max_batch, core.knobs.deadline),
            };
            if max_batch > 1 {
                if deadline.is_zero() {
                    // drain whatever is already queued
                    while pending.reqs.len() < max_batch {
                        match q.pop_front() {
                            Some(r) => pending.reqs.push(r),
                            None => break,
                        }
                    }
                } else {
                    // wait up to the deadline for the batch to fill
                    let cutoff = core.clock.now() + deadline.as_secs_f64();
                    'fill: while pending.reqs.len() < max_batch {
                        while let Some(r) = q.pop_front() {
                            pending.reqs.push(r);
                            if pending.reqs.len() >= max_batch {
                                break 'fill;
                            }
                        }
                        let left = cutoff - core.clock.now();
                        if left <= 0.0 {
                            break;
                        }
                        let (g, _) =
                            wait_timeout_recover(&rq.cv, q, Duration::from_secs_f64(left));
                        q = g;
                    }
                }
            }
        } // queue lock dropped before compute (and before any injected panic)
        round += 1;
        core.depths.beat(id);
        if let Some(f) = core.fault.as_ref() {
            if let Some(d) = f.stall(id, round) {
                f.record("stall", id, round);
                thread::sleep(d);
            }
            if f.kill_now(id, my_epoch, served_here) || f.panic_now(id, round) {
                f.record("panic", id, served_here);
                // `pending`'s drop guard requeues the picked batch; the
                // alive guard flips the liveness bit for the supervisor.
                // lint:allow(D3) chaos injection: this panic IS the fault under test
                panic!("injected fault: replica {id} panicked (epoch {my_epoch})");
            }
        }
        let picked = core.clock.now();
        SimPlatform::charge(core.knobs.dispatch);
        let samples: Vec<&Sample> = pending.reqs.iter().map(|r| &r.sample).collect();
        let probs = detector.score_batch(&samples);
        let done = core.clock.now();
        let batch = pending.reqs.len();
        core.note_service(Duration::from_secs_f64((done - picked).max(0.0)), batch);
        for (req, p) in pending.reqs.drain(..).zip(probs) {
            let latency = Duration::from_secs_f64((done - req.enqueued).max(0.0));
            let queue_delay = Duration::from_secs_f64((picked - req.enqueued).max(0.0));
            lock_recover(&core.hist).record(latency);
            core.served.fetch_add(1, Ordering::Relaxed);
            core.depths.leave(id);
            served_here += 1;
            let severed = req.seq != FLOOD_SEQ
                && core.fault.as_ref().map_or(false, |f| f.sever_reply(req.seq));
            if severed {
                if let Some(f) = core.fault.as_ref() {
                    f.record("sever", id, req.seq);
                }
                drop(req.reply); // client sees a dead channel, not a verdict
            } else {
                let _ = req.reply.send(Reply { prob: p, latency, queue_delay, shed: false });
            }
            if let Some(t) = tuner.as_mut() {
                t.observe(latency, queue_delay, latency.saturating_sub(queue_delay));
            }
        }
    }
}

/// Respawn replica `id` from the frozen snapshot under a fresh epoch.
fn respawn(core: &Arc<ServerCore>, id: usize, why: &'static str) {
    let det = {
        let proto = lock_recover(&core.proto);
        match proto.as_ref() {
            Some(d) => d.clone(),
            None => return, // unsupervised server holds no snapshot
        }
    };
    let epoch = core.epochs[id].fetch_add(1, Ordering::AcqRel) + 1;
    core.depths.set_alive(id, true);
    core.respawns.fetch_add(1, Ordering::Relaxed);
    if let Some(f) = core.fault.as_ref() {
        f.record("respawn", id, epoch);
    }
    eprintln!("[supervisor] replica {id} {why}: respawning (epoch {epoch})");
    let c = Arc::clone(core);
    let h = thread::spawn(move || run_replica(c, id, epoch, det));
    lock_recover(&core.handles).push(h);
    core.queues[id].cv.notify_all();
}

/// Supervisor loop: every `heartbeat`, respawn replicas that died
/// (liveness bit cleared by their unwind guard) or hung (non-empty queue
/// with a frozen heartbeat counter for longer than `hang`).
fn run_supervisor(core: Arc<ServerCore>) {
    let n = core.queues.len();
    let mut last_beats: Vec<u64> = (0..n).map(|i| core.depths.beats(i)).collect();
    let mut stuck_since: Vec<Option<f64>> = vec![None; n];
    loop {
        thread::sleep(core.guard.heartbeat);
        if !core.open.load(Ordering::Acquire) {
            return;
        }
        for i in 0..n {
            let dead = !core.depths.alive(i);
            let beats = core.depths.beats(i);
            let progressed = beats != last_beats[i];
            last_beats[i] = beats;
            let mut hung = false;
            if !dead {
                if progressed || core.depths.depth(i) == 0 {
                    stuck_since[i] = None;
                } else {
                    let since = *stuck_since[i].get_or_insert_with(|| core.clock.now());
                    hung = core.clock.now() - since >= core.guard.hang.as_secs_f64();
                }
            }
            if dead || hung {
                stuck_since[i] = None;
                respawn(&core, i, if dead { "died" } else { "hung" });
            }
        }
    }
}

impl StreamingServer {
    /// Full-control constructor: N replica workers, a micro-batch cap and
    /// fill deadline, a per-call dispatch charge, and the route policy.
    /// Prefer [`ServeSession`](crate::serve::ServeSession) unless you are
    /// wiring a custom [`RoutePolicy`].
    pub fn spawn(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
    ) -> StreamingServer {
        Self::spawn_tuned(detectors, max_batch, deadline, dispatch, policy, None)
    }

    /// [`Self::spawn`] with per-replica serve-batching autotune.  Each
    /// worker thread owns a [`ServeBatchTuner`] seeded from the
    /// configured `max_batch`/`deadline`; the loop reads the live knob
    /// pair every iteration and feeds every reply's window/queue/service
    /// split back.  With `autotune = None` the static knobs are read
    /// directly — the loop body is the identical code path, so the
    /// untuned server behaves exactly as before.
    pub fn spawn_tuned(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
        autotune: Option<ServeTuneCfg>,
    ) -> StreamingServer {
        Self::spawn_supervised(
            detectors,
            max_batch,
            deadline,
            dispatch,
            policy,
            autotune,
            GuardCfg::default(),
            None,
        )
    }

    /// The fully-guarded constructor: [`Self::spawn_tuned`] plus
    /// supervision / shedding knobs and an optional chaos plan.  With
    /// `guard == GuardCfg::default()` and `fault == None` this is
    /// byte-for-byte the unguarded server: no supervisor thread, no
    /// snapshot clone, no shed checks on the submit path.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_supervised(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
        autotune: Option<ServeTuneCfg>,
        guard: GuardCfg,
        fault: Option<Arc<FaultPlan>>,
    ) -> StreamingServer {
        Self::spawn_supervised_clocked(
            detectors,
            max_batch,
            deadline,
            dispatch,
            policy,
            autotune,
            guard,
            fault,
            Clock::real(),
        )
    }

    /// [`Self::spawn_supervised`] with an injected [`Clock`] — the
    /// timestamp source behind every enqueue/pickup/verdict split, the
    /// batch-fill deadline, and the supervisor's hang detector.  Tests
    /// pass [`Clock::manual`] to make the latency accounting
    /// wall-clock-free; pair a manual clock with a zero fill deadline
    /// (the fill cutoff never passes unless the test advances time).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_supervised_clocked(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
        autotune: Option<ServeTuneCfg>,
        guard: GuardCfg,
        fault: Option<Arc<FaultPlan>>,
        clock: Clock,
    ) -> StreamingServer {
        assert!(!detectors.is_empty(), "need at least one detector replica");
        let n = detectors.len();
        let supervise = !guard.heartbeat.is_zero();
        let proto = if supervise {
            Some(detectors[0].clone())
        } else {
            None
        };
        let core = Arc::new(ServerCore {
            queues: (0..n)
                .map(|_| ReplicaQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() })
                .collect(),
            depths: QueueDepths::new(n),
            epochs: (0..n).map(|_| AtomicU64::new(0)).collect(),
            open: AtomicBool::new(true),
            served: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            hist: Mutex::new(LatencyHist::new()),
            knobs: SpawnKnobs { max_batch, deadline, dispatch, autotune },
            guard,
            clock,
            svc_ewma_ns: AtomicU64::new(0),
            fault,
            respawns: AtomicU64::new(0),
            proto: Mutex::new(proto),
            handles: Mutex::new(Vec::with_capacity(n)),
            seq: AtomicU64::new(0),
        });
        for (id, detector) in detectors.into_iter().enumerate() {
            let c = Arc::clone(&core);
            let h = thread::spawn(move || run_replica(c, id, 0, detector));
            lock_recover(&core.handles).push(h);
        }
        let supervisor = if supervise {
            let c = Arc::clone(&core);
            Some(thread::spawn(move || run_supervisor(c)))
        } else {
            None
        };
        StreamingServer { core, policy, supervisor }
    }

    /// Legacy single-replica entry point (round-robin is a no-op at 1).
    pub fn start(detector: Detector, max_batch: usize, dispatch: Duration) -> StreamingServer {
        Self::start_sharded(vec![detector], max_batch, dispatch)
    }

    /// Legacy N-replica entry point: round-robin dispatch, no fill
    /// deadline.  Superseded by
    /// [`ServeSession`](crate::serve::ServeSession), which also threads
    /// planners and route policies; kept for drivers that already hold
    /// detector clones.
    pub fn start_sharded(
        detectors: Vec<Detector>,
        max_batch: usize,
        dispatch: Duration,
    ) -> StreamingServer {
        Self::spawn(
            detectors,
            max_batch,
            Duration::ZERO,
            dispatch,
            Arc::new(RoundRobin::new()),
        )
    }

    pub fn replicas(&self) -> usize {
        self.core.queues.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Current per-replica in-flight request gauges (+ heartbeat and
    /// liveness signals).
    pub fn queue_depths(&self) -> &QueueDepths {
        &self.core.depths
    }

    /// Replicas respawned by the supervisor so far.
    pub fn respawns(&self) -> u64 {
        self.core.respawns.load(Ordering::Relaxed)
    }

    /// Requests refused under overload so far.
    pub fn shed_count(&self) -> u64 {
        self.core.shed.load(Ordering::Relaxed)
    }

    /// Submit one sample WITHOUT waiting (open-loop client): the policy
    /// picks the replica, the reply arrives on the returned channel.
    /// With a non-zero shed budget the reply may be an immediate
    /// `Reply { shed: true }` refusal instead of a verdict.
    pub fn submit(&self, sample: &Sample) -> mpsc::Receiver<Reply> {
        let core = &self.core;
        let shard = self
            .policy
            .route(sample, &core.depths)
            .min(core.queues.len() - 1);
        let (rtx, rrx) = mpsc::channel();
        if !core.guard.shed_budget.is_zero() {
            let est = core.queue_delay_estimate(shard);
            if est > core.guard.shed_budget {
                core.shed.fetch_add(1, Ordering::Relaxed);
                let _ = rtx.send(Reply {
                    prob: 0.0,
                    latency: Duration::ZERO,
                    queue_delay: est,
                    shed: true,
                });
                return rrx;
            }
        }
        let seq = core.seq.fetch_add(1, Ordering::Relaxed);
        core.depths.enter(shard);
        let rq = &core.queues[shard];
        {
            let mut q = lock_recover(&rq.q);
            q.push_back(Request {
                sample: sample.clone(),
                enqueued: core.clock.now(),
                reply: rtx,
                seq,
            });
            if let Some(f) = core.fault.as_ref() {
                let burst = f.flood_burst(seq);
                if burst > 0 {
                    f.record("flood", shard, seq);
                    for _ in 0..burst {
                        // junk requests whose reply channels are born
                        // dead: pure queue pressure
                        let (jtx, _) = mpsc::channel();
                        core.depths.enter(shard);
                        q.push_back(Request {
                            sample: sample.clone(),
                            enqueued: core.clock.now(),
                            reply: jtx,
                            seq: FLOOD_SEQ,
                        });
                    }
                }
            }
        }
        rq.cv.notify_all();
        rrx
    }

    /// Submit one sample and wait for the verdict (closed-loop client).
    /// A severed reply channel (fault-injected drop, or a replica lost
    /// without respawn) degrades to an immediate `Reply { shed: true }`
    /// refusal instead of unwinding the client.
    pub fn infer(&self, sample: &Sample) -> Reply {
        match self.submit(sample).recv() {
            Ok(r) => r,
            Err(_) => Reply {
                prob: 0.0,
                latency: Duration::ZERO,
                queue_delay: Duration::ZERO,
                shed: true,
            },
        }
    }

    /// Drive a closed-loop stream of samples; returns the Table VI row.
    /// Latency and TPS cover THIS stream only (see `lifetime_served`).
    pub fn run_stream(self, samples: &[Sample], model_bytes: u64) -> ServeReport {
        let replicas = self.replicas();
        let mut hist = LatencyHist::new();
        let t0 = self.core.clock.now();
        for s in samples {
            hist.record(self.infer(s).latency);
        }
        let wall = Duration::from_secs_f64((self.core.clock.now() - t0).max(0.0));
        self.report(wall, hist, samples.len() as u64, model_bytes, replicas)
    }

    /// Drive the stream from `clients` concurrent closed-loop clients —
    /// a single closed-loop client can never keep more than one replica
    /// busy, so this is what the sharded throughput arm measures.
    pub fn run_stream_concurrent(
        self,
        samples: &[Sample],
        model_bytes: u64,
        clients: usize,
    ) -> ServeReport {
        let replicas = self.replicas();
        let clients = clients.clamp(1, samples.len().max(1));
        let chunk = ((samples.len() + clients - 1) / clients).max(1);
        let mut hist = LatencyHist::new();
        let t0 = self.core.clock.now();
        thread::scope(|sc| {
            let mut parts = Vec::new();
            for part in samples.chunks(chunk) {
                let srv = &self;
                parts.push(sc.spawn(move || {
                    let mut h = LatencyHist::new();
                    for smp in part {
                        h.record(srv.infer(smp).latency);
                    }
                    h
                }));
            }
            for p in parts {
                // a client thread that died mid-stream contributes no
                // latencies; the served counters in the core still hold
                if let Ok(h) = p.join() {
                    hist.merge(&h);
                }
            }
        });
        let wall = Duration::from_secs_f64((self.core.clock.now() - t0).max(0.0));
        self.report(wall, hist, samples.len() as u64, model_bytes, replicas)
    }

    /// Stop the replicas; returns (lifetime served count, lifetime
    /// latency histogram).  Used by drivers that account client-side
    /// (the open-loop generator) instead of through `run_stream*`.
    pub fn shutdown(self) -> (u64, LatencyHist) {
        self.finish()
    }

    fn report(
        self,
        wall: Duration,
        stream_hist: LatencyHist,
        stream_served: u64,
        model_bytes: u64,
        replicas: usize,
    ) -> ServeReport {
        let policy = self.policy.name();
        let (lifetime_served, _) = self.finish();
        ServeReport {
            served: stream_served,
            lifetime_served,
            wall,
            tps: stream_served as f64 / wall.as_secs_f64().max(1e-12),
            mean_latency: Duration::from_nanos(stream_hist.mean_ns() as u64),
            p99_latency: Duration::from_nanos(stream_hist.quantile_ns(0.99) as u64),
            model_bytes,
            replicas,
            policy,
        }
    }

    fn finish(self) -> (u64, LatencyHist) {
        let StreamingServer { core, supervisor, policy: _ } = self;
        core.open.store(false, Ordering::Release);
        for q in &core.queues {
            q.cv.notify_all();
        }
        if let Some(sup) = supervisor {
            let _ = sup.join();
        }
        // respawns can push new handles while we drain, so loop; a
        // panicked (fault-injected) incarnation joins as Err, which is
        // expected and harmless — its stats already live in the core.
        loop {
            let batch: Vec<_> = {
                let mut hs = lock_recover(&core.handles);
                hs.drain(..).collect()
            };
            if batch.is_empty() {
                break;
            }
            for h in batch {
                let _ = h.join();
            }
        }
        let served = core.served.load(Ordering::Relaxed);
        let hist = lock_recover(&core.hist).clone();
        (served, hist)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::runtime::fault::{FaultCfg, FaultPlan};
    use crate::util::prng::Rng;

    fn samples(n: usize) -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: n,
            n_attack: n / 4,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 2,
        })
        .samples
    }

    fn detector() -> Detector {
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        Detector::new(NativeDlrm::new(cfg, &mut Rng::new(1)), 0.5)
    }

    #[test]
    fn serves_all_requests_with_latency() {
        let ss = samples(20);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        let report = server.run_stream(&ss[..25], 1000);
        assert_eq!(report.served, 25);
        assert_eq!(report.lifetime_served, 25);
        assert_eq!(report.replicas, 1);
        assert_eq!(report.policy, "round_robin");
        assert!(report.tps > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        assert!(report.p99_latency >= report.mean_latency / 2);
    }

    #[test]
    fn stream_counts_exclude_prior_infer_traffic() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..5] {
            let r = server.infer(s);
            assert!((0.0..=1.0).contains(&r.prob));
            assert!(!r.shed);
            assert!(r.latency > Duration::ZERO);
            assert!(r.latency >= r.queue_delay);
        }
        let report = server.run_stream(&ss[5..8], 0);
        // the 5 warm-up `infer` calls must NOT inflate the stream stats…
        assert_eq!(report.served, 3);
        // …but stay visible in the lifetime counter
        assert_eq!(report.lifetime_served, 8);
    }

    #[test]
    fn sharded_replicas_serve_everything_and_agree() {
        let ss = samples(16);
        // verdicts from a single replica…
        let single = StreamingServer::start(detector(), 1, Duration::ZERO);
        let want: Vec<f32> = ss[..12].iter().map(|s| single.infer(s).prob).collect();
        let _ = single.run_stream(&ss[12..13], 0);
        // …must match a 3-replica shard (identical clones, any dispatch)
        let det = detector();
        let replicas = vec![det.clone(), det.clone(), det];
        let sharded = StreamingServer::start_sharded(replicas, 1, Duration::ZERO);
        assert_eq!(sharded.replicas(), 3);
        let got: Vec<f32> = ss[..12].iter().map(|s| sharded.infer(s).prob).collect();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-6, "shard changed verdict: {a} vs {b}");
        }
        let report = sharded.run_stream_concurrent(&ss[..16], 0, 4);
        assert_eq!(report.served, 16);
        assert_eq!(report.lifetime_served, 12 + 16);
        assert_eq!(report.replicas, 3);
    }

    #[test]
    fn queue_gauges_drain_after_serving() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..6] {
            let _ = server.infer(s);
        }
        // closed loop: every request was answered, so gauges are back to 0
        assert_eq!(server.queue_depths().depth(0), 0);
        let (lifetime, hist) = server.shutdown();
        assert_eq!(lifetime, 6);
        assert_eq!(hist.count(), 6);
    }

    #[test]
    fn supervisor_respawns_killed_replica_and_no_request_is_lost() {
        let ss = samples(10);
        let plan = FaultPlan::new(FaultCfg {
            enabled: true,
            kill_replica: Some(0),
            kill_after: 2,
            ..FaultCfg::default()
        });
        let guard = GuardCfg {
            heartbeat: Duration::from_millis(2),
            ..GuardCfg::default()
        };
        let server = StreamingServer::spawn_supervised(
            vec![detector()],
            1,
            Duration::ZERO,
            Duration::ZERO,
            Arc::new(RoundRobin::new()),
            None,
            guard,
            Some(Arc::clone(&plan)),
        );
        let receivers: Vec<_> = ss[..8].iter().map(|s| server.submit(s)).collect();
        let mut got = 0;
        for rx in receivers {
            let r = rx.recv_timeout(Duration::from_secs(20)).expect("served after respawn");
            assert!(!r.shed);
            assert!((0.0..=1.0).contains(&r.prob));
            got += 1;
        }
        assert_eq!(got, 8, "every accepted request must be served");
        assert!(server.respawns() >= 1, "supervisor must log a respawn");
        assert!(plan.event_count("panic") >= 1);
        assert!(plan.event_count("respawn") >= 1);
        let (lifetime, _) = server.shutdown();
        assert_eq!(lifetime, 8);
    }

    /// Regression for the D3 burn-down: a panic while HOLDING a queue
    /// mutex poisons it; every lock site on the request path recovers
    /// (util::sync::lock_recover) instead of unwinding, so not a single
    /// subsequent request is lost.
    #[test]
    fn poisoned_queue_mutex_loses_no_requests() {
        let ss = samples(12);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        let core = Arc::clone(&server.core);
        let poisoner = thread::spawn(move || {
            let _g = core.queues[0].q.lock().unwrap();
            panic!("poison the queue mutex");
        });
        assert!(poisoner.join().is_err(), "poisoner must panic while holding the lock");
        assert!(server.core.queues[0].q.is_poisoned(), "mutex must actually be poisoned");
        for s in &ss[..10] {
            let r = server.infer(s);
            assert!(!r.shed, "request shed after poison");
            assert!((0.0..=1.0).contains(&r.prob));
        }
        let (lifetime, hist) = server.shutdown();
        assert_eq!(lifetime, 10, "a request was lost to the poisoned mutex");
        assert_eq!(hist.count(), 10);
    }

    /// The injected clock reaches every timestamp read: under a manual
    /// clock that never advances, latency splits are exactly zero while
    /// requests still flow (worker wakeups are condvar-driven).
    #[test]
    fn manual_clock_server_is_wall_clock_free() {
        let ss = samples(6);
        let server = StreamingServer::spawn_supervised_clocked(
            vec![detector()],
            1,
            Duration::ZERO,
            Duration::ZERO,
            Arc::new(RoundRobin::new()),
            None,
            GuardCfg::default(),
            None,
            Clock::manual(),
        );
        for s in &ss[..4] {
            let r = server.infer(s);
            assert!(!r.shed);
            assert_eq!(r.latency, Duration::ZERO, "manual clock never advanced");
            assert_eq!(r.queue_delay, Duration::ZERO);
        }
        let (lifetime, _) = server.shutdown();
        assert_eq!(lifetime, 4);
    }

    #[test]
    fn overload_sheds_instead_of_queueing_unboundedly() {
        let ss = samples(30);
        let guard = GuardCfg {
            shed_budget: Duration::from_nanos(1),
            ..GuardCfg::default()
        };
        let server = StreamingServer::spawn_supervised(
            vec![detector()],
            1,
            Duration::ZERO,
            Duration::from_millis(5), // slow dispatch: queues build instantly
            Arc::new(RoundRobin::new()),
            None,
            guard,
            None,
        );
        // first request seeds the service-time EWMA
        let warm = server.infer(&ss[0]);
        assert!(!warm.shed);
        // rapid-fire: the worker is busy ≥5 ms per request, so later
        // submits see depth ≥ 1 and an estimate ≫ 1 ns → shed
        let receivers: Vec<_> = ss[1..21].iter().map(|s| server.submit(s)).collect();
        let mut served = 0;
        let mut shed = 0;
        for rx in receivers {
            let r = rx.recv_timeout(Duration::from_secs(20)).expect("answered or shed");
            if r.shed {
                shed += 1;
                assert_eq!(r.latency, Duration::ZERO);
            } else {
                served += 1;
            }
        }
        assert_eq!(served + shed, 20, "every request answered exactly once");
        assert!(shed >= 1, "overload must shed");
        assert_eq!(server.shed_count(), shed as u64);
        let (lifetime, _) = server.shutdown();
        assert_eq!(lifetime, 1 + served as u64);
    }
}
