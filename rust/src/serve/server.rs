//! Streaming inference server: a worker thread consumes a request channel
//! and answers with verdicts; the driver measures per-request latency and
//! sustained TPS (Table VI's configuration: batch size 1, industrial
//! streaming).  A micro-batching mode (`max_batch > 1`) drains whatever is
//! queued up to the cap — the standard serving-router trade-off.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::platform::SimPlatform;
use crate::powersys::dataset::Sample;
use crate::serve::detector::Detector;
use crate::util::stats::LatencyHist;

/// One in-flight request.
struct Request {
    sample: Sample,
    enqueued: Instant,
    reply: mpsc::Sender<(f32, Duration)>,
}

pub struct StreamingServer {
    tx: mpsc::Sender<Request>,
    handle: Option<thread::JoinHandle<ServerStats>>,
}

struct ServerStats {
    served: u64,
    hist: LatencyHist,
}

#[derive(Debug)]
pub struct ServeReport {
    pub served: u64,
    pub wall: Duration,
    pub tps: f64,
    pub mean_latency: Duration,
    pub p99_latency: Duration,
    /// Peak device memory ≈ model bytes + activation slack.
    pub model_bytes: u64,
}

impl StreamingServer {
    /// Spawn the serving thread around a trained detector.  `dispatch`
    /// is charged per inference call (the platform's launch overhead).
    pub fn start(mut detector: Detector, max_batch: usize, dispatch: Duration) -> StreamingServer {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = thread::spawn(move || {
            let mut stats = ServerStats { served: 0, hist: LatencyHist::new() };
            let mut pending: Vec<Request> = Vec::new();
            loop {
                // blocking receive for the first request
                let first = match rx.recv() {
                    Ok(r) => r,
                    Err(_) => break,
                };
                pending.push(first);
                // micro-batch: drain whatever is already queued
                while pending.len() < max_batch {
                    match rx.try_recv() {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
                SimPlatform::charge(dispatch);
                let samples: Vec<&Sample> = pending.iter().map(|r| &r.sample).collect();
                let probs = detector.score_batch(&samples);
                let now = Instant::now();
                for (req, p) in pending.drain(..).zip(probs) {
                    let lat = now.duration_since(req.enqueued);
                    stats.hist.record(lat);
                    stats.served += 1;
                    let _ = req.reply.send((p, lat));
                }
            }
            stats
        });
        StreamingServer { tx, handle: Some(handle) }
    }

    /// Submit one sample and wait for the verdict (closed-loop client).
    pub fn infer(&self, sample: &Sample) -> (f32, Duration) {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request { sample: sample.clone(), enqueued: Instant::now(), reply: rtx })
            .expect("server alive");
        rrx.recv().expect("server replies")
    }

    /// Drive a closed-loop stream of samples; returns the Table VI row.
    pub fn run_stream(self, samples: &[Sample], model_bytes: u64) -> ServeReport {
        let t0 = Instant::now();
        for s in samples {
            let _ = self.infer(s);
        }
        let wall = t0.elapsed();
        let stats = self.finish();
        ServeReport {
            served: stats.served,
            wall,
            tps: stats.served as f64 / wall.as_secs_f64(),
            mean_latency: Duration::from_nanos(stats.hist.mean_ns() as u64),
            p99_latency: Duration::from_nanos(stats.hist.quantile_ns(0.99) as u64),
            model_bytes,
        }
    }

    fn finish(mut self) -> ServerStats {
        drop(self.tx);
        self.handle.take().unwrap().join().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    fn samples(n: usize) -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: n,
            n_attack: n / 4,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 2,
        })
        .samples
    }

    fn detector() -> Detector {
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        Detector::new(NativeDlrm::new(cfg, &mut Rng::new(1)), 0.5)
    }

    #[test]
    fn serves_all_requests_with_latency() {
        let ss = samples(20);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        let report = server.run_stream(&ss[..25], 1000);
        assert_eq!(report.served, 25);
        assert!(report.tps > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        assert!(report.p99_latency >= report.mean_latency / 2);
    }

    #[test]
    fn verdict_probabilities_sane() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..5] {
            let (p, lat) = server.infer(s);
            assert!((0.0..=1.0).contains(&p));
            assert!(lat > Duration::ZERO);
        }
        let report = server.run_stream(&ss[5..8], 0);
        assert_eq!(report.served, 8); // 5 singles + 3 streamed
    }
}
