//! Streaming inference server: replica worker threads consume request
//! channels and answer with verdicts.  Which replica serves a request is
//! decided by a pluggable [`RoutePolicy`] (`serve::router`) — round-robin,
//! least-queued, or plan-affinity shard routing — and replicas are clones
//! of one trained detector, so verdicts are bitwise independent of the
//! policy (pinned by `tests/serve_equivalence.rs`).
//!
//! **Micro-batching** (`max_batch > 1`): a replica drains whatever is
//! queued up to the cap; with a non-zero `deadline` it additionally waits
//! up to that long for the batch to fill — the standard serving-router
//! latency/throughput trade-off.  Batching never changes scores.
//!
//! **Accounting**: every [`Reply`] carries the queue-delay / service-time
//! split (enqueue → pickup vs pickup → verdict), which is what the
//! open-loop generator (`serve::load`) needs to attribute the attack
//! window.  [`ServeReport`] counts the driven stream only; requests
//! served before `run_stream*` (e.g. warm-up `infer` calls) appear under
//! `lifetime_served` instead of inflating the stream TPS.
//!
//! Constructing a server by hand is the low-level path — prefer the
//! [`ServeSession`](crate::serve::ServeSession) builder, which threads
//! the trained planner, policy, replica count and deadlines end to end.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::platform::SimPlatform;
use crate::powersys::dataset::Sample;
use crate::runtime::autotune::{ServeBatchTuner, ServeTuneCfg};
use crate::serve::detector::Detector;
use crate::serve::router::{QueueDepths, RoundRobin, RoutePolicy};
use crate::util::clock::Clock;
use crate::util::stats::LatencyHist;

/// One in-flight request.
struct Request {
    sample: Sample,
    enqueued: Instant,
    reply: mpsc::Sender<Reply>,
}

/// One answered request.
#[derive(Clone, Copy, Debug)]
pub struct Reply {
    pub prob: f32,
    /// End-to-end latency: enqueue → verdict delivered.
    pub latency: Duration,
    /// Enqueue → batch pickup: router queueing plus any micro-batch
    /// deadline wait.
    pub queue_delay: Duration,
}

impl Reply {
    /// Pickup → verdict: dispatch charge + model compute.
    pub fn service_time(&self) -> Duration {
        self.latency.saturating_sub(self.queue_delay)
    }
}

pub struct StreamingServer {
    txs: Vec<mpsc::Sender<Request>>,
    handles: Vec<thread::JoinHandle<ServerStats>>,
    depths: Arc<QueueDepths>,
    policy: Arc<dyn RoutePolicy>,
}

struct ServerStats {
    served: u64,
    hist: LatencyHist,
}

#[derive(Debug)]
pub struct ServeReport {
    /// Requests served by THIS `run_stream*` call (stream-only).
    pub served: u64,
    /// Requests served over the replicas' whole lifetime — includes any
    /// `infer`/`submit` traffic before the stream.  (The pre-redesign
    /// report conflated this with `served`, inflating `tps`.)
    pub lifetime_served: u64,
    pub wall: Duration,
    /// Stream-only throughput: `served / wall`.
    pub tps: f64,
    /// Stream-only latency stats, recorded at the closed-loop clients.
    pub mean_latency: Duration,
    pub p99_latency: Duration,
    /// Peak device memory ≈ model bytes + activation slack.
    pub model_bytes: u64,
    /// Detector replicas that served the stream.
    pub replicas: usize,
    /// Route policy that dispatched the stream.
    pub policy: &'static str,
}

impl StreamingServer {
    /// Full-control constructor: N replica workers, a micro-batch cap and
    /// fill deadline, a per-call dispatch charge, and the route policy.
    /// Prefer [`ServeSession`](crate::serve::ServeSession) unless you are
    /// wiring a custom [`RoutePolicy`].
    pub fn spawn(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
    ) -> StreamingServer {
        Self::spawn_tuned(detectors, max_batch, deadline, dispatch, policy, None)
    }

    /// [`Self::spawn`] with per-replica serve-batching autotune.  Each
    /// worker thread owns a [`ServeBatchTuner`] seeded from the
    /// configured `max_batch`/`deadline`; the loop reads the live knob
    /// pair every iteration and feeds every reply's window/queue/service
    /// split back.  With `autotune = None` the static knobs are read
    /// directly — the loop body is the identical code path, so the
    /// untuned server behaves exactly as before.
    pub fn spawn_tuned(
        detectors: Vec<Detector>,
        max_batch: usize,
        deadline: Duration,
        dispatch: Duration,
        policy: Arc<dyn RoutePolicy>,
        autotune: Option<ServeTuneCfg>,
    ) -> StreamingServer {
        assert!(!detectors.is_empty(), "need at least one detector replica");
        let depths = Arc::new(QueueDepths::new(detectors.len()));
        let mut txs = Vec::with_capacity(detectors.len());
        let mut handles = Vec::with_capacity(detectors.len());
        for (id, mut detector) in detectors.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Request>();
            let depths = Arc::clone(&depths);
            let handle = thread::spawn(move || {
                let mut tuner = autotune
                    .map(|c| ServeBatchTuner::new(c, max_batch, deadline, Clock::real()));
                let knobs = tuner.as_ref().map(|t| t.knobs());
                let mut stats = ServerStats { served: 0, hist: LatencyHist::new() };
                let mut pending: Vec<Request> = Vec::new();
                loop {
                    // blocking receive for the first request
                    let first = match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    };
                    pending.push(first);
                    let (max_batch, deadline) = match &knobs {
                        Some(k) => (k.max_batch(), k.deadline()),
                        None => (max_batch, deadline),
                    };
                    if max_batch > 1 {
                        if deadline.is_zero() {
                            // drain whatever is already queued
                            while pending.len() < max_batch {
                                match rx.try_recv() {
                                    Ok(r) => pending.push(r),
                                    Err(_) => break,
                                }
                            }
                        } else {
                            // wait up to the deadline for the batch to fill
                            let cutoff = Instant::now() + deadline;
                            while pending.len() < max_batch {
                                let left = match cutoff
                                    .checked_duration_since(Instant::now())
                                {
                                    Some(d) if !d.is_zero() => d,
                                    _ => break,
                                };
                                match rx.recv_timeout(left) {
                                    Ok(r) => pending.push(r),
                                    Err(_) => break,
                                }
                            }
                        }
                    }
                    let picked = Instant::now();
                    SimPlatform::charge(dispatch);
                    let samples: Vec<&Sample> =
                        pending.iter().map(|r| &r.sample).collect();
                    let probs = detector.score_batch(&samples);
                    let done = Instant::now();
                    for (req, p) in pending.drain(..).zip(probs) {
                        let latency = done.saturating_duration_since(req.enqueued);
                        let queue_delay =
                            picked.saturating_duration_since(req.enqueued);
                        stats.hist.record(latency);
                        stats.served += 1;
                        depths.leave(id);
                        let _ = req.reply.send(Reply { prob: p, latency, queue_delay });
                        if let Some(t) = tuner.as_mut() {
                            t.observe(
                                latency,
                                queue_delay,
                                latency.saturating_sub(queue_delay),
                            );
                        }
                    }
                }
                stats
            });
            txs.push(tx);
            handles.push(handle);
        }
        StreamingServer { txs, handles, depths, policy }
    }

    /// Legacy single-replica entry point (round-robin is a no-op at 1).
    pub fn start(detector: Detector, max_batch: usize, dispatch: Duration) -> StreamingServer {
        Self::start_sharded(vec![detector], max_batch, dispatch)
    }

    /// Legacy N-replica entry point: round-robin dispatch, no fill
    /// deadline.  Superseded by
    /// [`ServeSession`](crate::serve::ServeSession), which also threads
    /// planners and route policies; kept for drivers that already hold
    /// detector clones.
    pub fn start_sharded(
        detectors: Vec<Detector>,
        max_batch: usize,
        dispatch: Duration,
    ) -> StreamingServer {
        Self::spawn(
            detectors,
            max_batch,
            Duration::ZERO,
            dispatch,
            Arc::new(RoundRobin::new()),
        )
    }

    pub fn replicas(&self) -> usize {
        self.txs.len()
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Current per-replica in-flight request gauges.
    pub fn queue_depths(&self) -> &QueueDepths {
        &self.depths
    }

    /// Submit one sample WITHOUT waiting (open-loop client): the policy
    /// picks the replica, the reply arrives on the returned channel.
    pub fn submit(&self, sample: &Sample) -> mpsc::Receiver<Reply> {
        let shard = self.policy.route(sample, &self.depths).min(self.txs.len() - 1);
        self.depths.enter(shard);
        let (rtx, rrx) = mpsc::channel();
        self.txs[shard]
            .send(Request {
                sample: sample.clone(),
                enqueued: Instant::now(),
                reply: rtx,
            })
            .expect("server alive");
        rrx
    }

    /// Submit one sample and wait for the verdict (closed-loop client).
    pub fn infer(&self, sample: &Sample) -> Reply {
        self.submit(sample).recv().expect("server replies")
    }

    /// Drive a closed-loop stream of samples; returns the Table VI row.
    /// Latency and TPS cover THIS stream only (see `lifetime_served`).
    pub fn run_stream(self, samples: &[Sample], model_bytes: u64) -> ServeReport {
        let replicas = self.replicas();
        let mut hist = LatencyHist::new();
        let t0 = Instant::now();
        for s in samples {
            hist.record(self.infer(s).latency);
        }
        let wall = t0.elapsed();
        self.report(wall, hist, samples.len() as u64, model_bytes, replicas)
    }

    /// Drive the stream from `clients` concurrent closed-loop clients —
    /// a single closed-loop client can never keep more than one replica
    /// busy, so this is what the sharded throughput arm measures.
    pub fn run_stream_concurrent(
        self,
        samples: &[Sample],
        model_bytes: u64,
        clients: usize,
    ) -> ServeReport {
        let replicas = self.replicas();
        let clients = clients.clamp(1, samples.len().max(1));
        let chunk = ((samples.len() + clients - 1) / clients).max(1);
        let mut hist = LatencyHist::new();
        let t0 = Instant::now();
        thread::scope(|sc| {
            let mut parts = Vec::new();
            for part in samples.chunks(chunk) {
                let srv = &self;
                parts.push(sc.spawn(move || {
                    let mut h = LatencyHist::new();
                    for smp in part {
                        h.record(srv.infer(smp).latency);
                    }
                    h
                }));
            }
            for p in parts {
                hist.merge(&p.join().unwrap());
            }
        });
        let wall = t0.elapsed();
        self.report(wall, hist, samples.len() as u64, model_bytes, replicas)
    }

    /// Stop the replicas; returns (lifetime served count, lifetime
    /// latency histogram).  Used by drivers that account client-side
    /// (the open-loop generator) instead of through `run_stream*`.
    pub fn shutdown(self) -> (u64, LatencyHist) {
        let stats = self.finish();
        (stats.served, stats.hist)
    }

    fn report(
        self,
        wall: Duration,
        stream_hist: LatencyHist,
        stream_served: u64,
        model_bytes: u64,
        replicas: usize,
    ) -> ServeReport {
        let policy = self.policy.name();
        let lifetime = self.finish();
        ServeReport {
            served: stream_served,
            lifetime_served: lifetime.served,
            wall,
            tps: stream_served as f64 / wall.as_secs_f64().max(1e-12),
            mean_latency: Duration::from_nanos(stream_hist.mean_ns() as u64),
            p99_latency: Duration::from_nanos(stream_hist.quantile_ns(0.99) as u64),
            model_bytes,
            replicas,
            policy,
        }
    }

    fn finish(mut self) -> ServerStats {
        self.txs.clear(); // drop every sender so the workers exit
        let mut merged = ServerStats { served: 0, hist: LatencyHist::new() };
        for h in self.handles.drain(..) {
            let s = h.join().unwrap();
            merged.served += s.served;
            merged.hist.merge(&s.hist);
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    fn samples(n: usize) -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: n,
            n_attack: n / 4,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 2,
        })
        .samples
    }

    fn detector() -> Detector {
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        Detector::new(NativeDlrm::new(cfg, &mut Rng::new(1)), 0.5)
    }

    #[test]
    fn serves_all_requests_with_latency() {
        let ss = samples(20);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        let report = server.run_stream(&ss[..25], 1000);
        assert_eq!(report.served, 25);
        assert_eq!(report.lifetime_served, 25);
        assert_eq!(report.replicas, 1);
        assert_eq!(report.policy, "round_robin");
        assert!(report.tps > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        assert!(report.p99_latency >= report.mean_latency / 2);
    }

    #[test]
    fn stream_counts_exclude_prior_infer_traffic() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..5] {
            let r = server.infer(s);
            assert!((0.0..=1.0).contains(&r.prob));
            assert!(r.latency > Duration::ZERO);
            assert!(r.latency >= r.queue_delay);
        }
        let report = server.run_stream(&ss[5..8], 0);
        // the 5 warm-up `infer` calls must NOT inflate the stream stats…
        assert_eq!(report.served, 3);
        // …but stay visible in the lifetime counter
        assert_eq!(report.lifetime_served, 8);
    }

    #[test]
    fn sharded_replicas_serve_everything_and_agree() {
        let ss = samples(16);
        // verdicts from a single replica…
        let single = StreamingServer::start(detector(), 1, Duration::ZERO);
        let want: Vec<f32> = ss[..12].iter().map(|s| single.infer(s).prob).collect();
        let _ = single.run_stream(&ss[12..13], 0);
        // …must match a 3-replica shard (identical clones, any dispatch)
        let det = detector();
        let replicas = vec![det.clone(), det.clone(), det];
        let sharded = StreamingServer::start_sharded(replicas, 1, Duration::ZERO);
        assert_eq!(sharded.replicas(), 3);
        let got: Vec<f32> = ss[..12].iter().map(|s| sharded.infer(s).prob).collect();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-6, "shard changed verdict: {a} vs {b}");
        }
        let report = sharded.run_stream_concurrent(&ss[..16], 0, 4);
        assert_eq!(report.served, 16);
        assert_eq!(report.lifetime_served, 12 + 16);
        assert_eq!(report.replicas, 3);
    }

    #[test]
    fn queue_gauges_drain_after_serving() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..6] {
            let _ = server.infer(s);
        }
        // closed loop: every request was answered, so gauges are back to 0
        assert_eq!(server.queue_depths().depth(0), 0);
        let (lifetime, hist) = server.shutdown();
        assert_eq!(lifetime, 6);
        assert_eq!(hist.count(), 6);
    }
}
