//! Streaming inference server: worker threads consume request channels
//! and answer with verdicts; the driver measures per-request latency and
//! sustained TPS (Table VI's configuration: batch size 1, industrial
//! streaming).  A micro-batching mode (`max_batch > 1`) drains whatever is
//! queued up to the cap — the standard serving-router trade-off.
//!
//! **Sharded mode** (exec refactor): [`StreamingServer::start_sharded`]
//! runs N detector replicas, one per worker thread, with round-robin
//! dispatch and merged latency accounting — the serving analogue of the
//! exec layer's intra-step parallelism, letting a Table VI-style stream
//! saturate multiple cores.  Replicas are identical trained models, so
//! verdicts are independent of which shard serves a request.
//!
//! **Access planning** (access refactor): each replica's [`Detector`]
//! owns its batch + `BatchPlan` scratch, so request handling reuses
//! per-replica plan buffers (column extraction, dedup, unit-bag offsets)
//! instead of re-deriving index work per request — allocation-free in
//! steady state, with no cross-replica synchronization.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::platform::SimPlatform;
use crate::powersys::dataset::Sample;
use crate::serve::detector::Detector;
use crate::util::stats::LatencyHist;

/// One in-flight request.
struct Request {
    sample: Sample,
    enqueued: Instant,
    reply: mpsc::Sender<(f32, Duration)>,
}

pub struct StreamingServer {
    txs: Vec<mpsc::Sender<Request>>,
    handles: Vec<thread::JoinHandle<ServerStats>>,
    /// Round-robin dispatch cursor.
    next: AtomicUsize,
}

struct ServerStats {
    served: u64,
    hist: LatencyHist,
}

#[derive(Debug)]
pub struct ServeReport {
    pub served: u64,
    pub wall: Duration,
    pub tps: f64,
    pub mean_latency: Duration,
    pub p99_latency: Duration,
    /// Peak device memory ≈ model bytes + activation slack.
    pub model_bytes: u64,
    /// Detector replicas that served the stream.
    pub replicas: usize,
}

impl StreamingServer {
    /// Spawn a single serving thread around a trained detector.
    /// `dispatch` is charged per inference call (the platform's launch
    /// overhead).
    pub fn start(detector: Detector, max_batch: usize, dispatch: Duration) -> StreamingServer {
        Self::start_sharded(vec![detector], max_batch, dispatch)
    }

    /// N-replica sharded serving: one detector per worker thread,
    /// round-robin request dispatch, latency histograms merged at
    /// shutdown.  Pass replicas cloned from one trained detector so every
    /// shard issues identical verdicts.
    pub fn start_sharded(
        detectors: Vec<Detector>,
        max_batch: usize,
        dispatch: Duration,
    ) -> StreamingServer {
        assert!(!detectors.is_empty(), "need at least one detector replica");
        let mut txs = Vec::with_capacity(detectors.len());
        let mut handles = Vec::with_capacity(detectors.len());
        for mut detector in detectors {
            let (tx, rx) = mpsc::channel::<Request>();
            let handle = thread::spawn(move || {
                let mut stats = ServerStats { served: 0, hist: LatencyHist::new() };
                let mut pending: Vec<Request> = Vec::new();
                loop {
                    // blocking receive for the first request
                    let first = match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break,
                    };
                    pending.push(first);
                    // micro-batch: drain whatever is already queued
                    while pending.len() < max_batch {
                        match rx.try_recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                    }
                    SimPlatform::charge(dispatch);
                    let samples: Vec<&Sample> = pending.iter().map(|r| &r.sample).collect();
                    let probs = detector.score_batch(&samples);
                    let now = Instant::now();
                    for (req, p) in pending.drain(..).zip(probs) {
                        let lat = now.duration_since(req.enqueued);
                        stats.hist.record(lat);
                        stats.served += 1;
                        let _ = req.reply.send((p, lat));
                    }
                }
                stats
            });
            txs.push(tx);
            handles.push(handle);
        }
        StreamingServer { txs, handles, next: AtomicUsize::new(0) }
    }

    pub fn replicas(&self) -> usize {
        self.txs.len()
    }

    /// Submit one sample and wait for the verdict (closed-loop client).
    /// Requests round-robin across replicas.
    pub fn infer(&self, sample: &Sample) -> (f32, Duration) {
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        let (rtx, rrx) = mpsc::channel();
        self.txs[shard]
            .send(Request { sample: sample.clone(), enqueued: Instant::now(), reply: rtx })
            .expect("server alive");
        rrx.recv().expect("server replies")
    }

    /// Drive a closed-loop stream of samples; returns the Table VI row.
    pub fn run_stream(self, samples: &[Sample], model_bytes: u64) -> ServeReport {
        let replicas = self.replicas();
        let t0 = Instant::now();
        for s in samples {
            let _ = self.infer(s);
        }
        let wall = t0.elapsed();
        self.report(wall, model_bytes, replicas)
    }

    /// Drive the stream from `clients` concurrent closed-loop clients —
    /// a single closed-loop client can never keep more than one replica
    /// busy, so this is what the sharded throughput arm measures.
    pub fn run_stream_concurrent(
        self,
        samples: &[Sample],
        model_bytes: u64,
        clients: usize,
    ) -> ServeReport {
        let replicas = self.replicas();
        let clients = clients.clamp(1, samples.len().max(1));
        let chunk = ((samples.len() + clients - 1) / clients).max(1);
        let t0 = Instant::now();
        thread::scope(|s| {
            for part in samples.chunks(chunk) {
                let srv = &self;
                s.spawn(move || {
                    for smp in part {
                        let _ = srv.infer(smp);
                    }
                });
            }
        });
        let wall = t0.elapsed();
        self.report(wall, model_bytes, replicas)
    }

    fn report(self, wall: Duration, model_bytes: u64, replicas: usize) -> ServeReport {
        let stats = self.finish();
        ServeReport {
            served: stats.served,
            wall,
            tps: stats.served as f64 / wall.as_secs_f64(),
            mean_latency: Duration::from_nanos(stats.hist.mean_ns() as u64),
            p99_latency: Duration::from_nanos(stats.hist.quantile_ns(0.99) as u64),
            model_bytes,
            replicas,
        }
    }

    fn finish(mut self) -> ServerStats {
        self.txs.clear(); // drop every sender so the workers exit
        let mut merged = ServerStats { served: 0, hist: LatencyHist::new() };
        for h in self.handles.drain(..) {
            let s = h.join().unwrap();
            merged.served += s.served;
            merged.hist.merge(&s.hist);
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    fn samples(n: usize) -> Vec<Sample> {
        generate(&DatasetCfg {
            n_normal: n,
            n_attack: n / 4,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 2,
        })
        .samples
    }

    fn detector() -> Detector {
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        Detector::new(NativeDlrm::new(cfg, &mut Rng::new(1)), 0.5)
    }

    #[test]
    fn serves_all_requests_with_latency() {
        let ss = samples(20);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        let report = server.run_stream(&ss[..25], 1000);
        assert_eq!(report.served, 25);
        assert_eq!(report.replicas, 1);
        assert!(report.tps > 0.0);
        assert!(report.mean_latency > Duration::ZERO);
        assert!(report.p99_latency >= report.mean_latency / 2);
    }

    #[test]
    fn verdict_probabilities_sane() {
        let ss = samples(8);
        let server = StreamingServer::start(detector(), 1, Duration::ZERO);
        for s in &ss[..5] {
            let (p, lat) = server.infer(s);
            assert!((0.0..=1.0).contains(&p));
            assert!(lat > Duration::ZERO);
        }
        let report = server.run_stream(&ss[5..8], 0);
        assert_eq!(report.served, 8); // 5 singles + 3 streamed
    }

    #[test]
    fn sharded_replicas_serve_everything_and_agree() {
        let ss = samples(16);
        // verdicts from a single replica…
        let single = StreamingServer::start(detector(), 1, Duration::ZERO);
        let want: Vec<f32> = ss[..12].iter().map(|s| single.infer(s).0).collect();
        let _ = single.run_stream(&ss[12..13], 0);
        // …must match a 3-replica shard (identical clones, any dispatch)
        let det = detector();
        let replicas = vec![det.clone(), det.clone(), det];
        let sharded = StreamingServer::start_sharded(replicas, 1, Duration::ZERO);
        assert_eq!(sharded.replicas(), 3);
        let got: Vec<f32> = ss[..12].iter().map(|s| sharded.infer(s).0).collect();
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-6, "shard changed verdict: {a} vs {b}");
        }
        let report = sharded.run_stream_concurrent(&ss[..16], 0, 4);
        assert_eq!(report.served, 12 + 16);
        assert_eq!(report.replicas, 3);
    }
}
