//! Open-loop load generation: Poisson arrivals at a target rate,
//! submitted WITHOUT waiting for replies — unlike the closed-loop
//! drivers (`run_stream*`), which can never observe queueing because
//! each client has at most one request in flight.
//!
//! This is the measurement the paper's real-time claim actually needs:
//! under industrial streaming load the attacker's undetected window is
//! the end-to-end detection latency *including queueing*, so the report
//! splits every request's window into queue delay (enqueue → pickup)
//! and service time (pickup → verdict) and summarizes the window
//! percentiles under load.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use crate::powersys::dataset::Sample;
use crate::serve::server::{Reply, StreamingServer};
use crate::util::prng::Rng;
use crate::util::stats::percentile;

/// Open-loop generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopCfg {
    /// Target Poisson arrival rate (requests per second).
    pub rate_per_sec: f64,
    /// Seed of the (deterministic) arrival process.
    pub seed: u64,
}

/// What an open-loop run measured.
#[derive(Debug)]
pub struct OpenLoopReport {
    /// Requests the generator offered (== `samples.len()`).
    pub offered: usize,
    /// Requests that came back with a verdict.  Normally every offered
    /// request; see `dropped` and `shed` for the exceptions.
    pub served: u64,
    /// Requests whose reply channel disconnected (or timed out) before a
    /// verdict arrived (a replica dropped the sender — e.g. a session
    /// shutdown racing the drain, or an injected reply-sever fault).
    /// Counted instead of aborting the run; excluded from every latency
    /// statistic.  Distinct from `shed`: a drop is silent loss, a shed
    /// is an explicit immediate refusal.
    pub dropped: usize,
    /// Requests the router refused under overload (`Reply::shed`) —
    /// answered immediately, never queued, excluded from latency stats.
    pub shed: usize,
    /// Replicas the supervisor respawned during the run.
    pub respawns: u64,
    pub wall: Duration,
    /// Configured arrival rate (requests/s).
    pub offered_rate: f64,
    /// `served / wall` — sags below `offered_rate` once queues grow.
    pub achieved_rate: f64,
    /// Attack-window percentiles: end-to-end detection latency under
    /// load (queue delay + service time).
    pub mean_window: Duration,
    pub p50_window: Duration,
    pub p99_window: Duration,
    pub max_window: Duration,
    /// Queueing side of the window (enqueue → batch pickup).
    pub mean_queue_delay: Duration,
    pub p99_queue_delay: Duration,
    /// Compute side of the window (pickup → verdict).
    pub mean_service: Duration,
    pub p99_service: Duration,
    pub replicas: usize,
    pub policy: &'static str,
    /// p99 attack window over the SECOND HALF of served requests in
    /// arrival order — the post-recovery tail a kill/respawn bench arm
    /// compares against its fault-free twin.
    pub tail_p99_window: Duration,
    /// Sorted per-request windows in seconds (for bench arms /
    /// custom percentiles).
    pub window_samples: Vec<f64>,
}

/// Drive `samples` through the server as an open-loop Poisson stream at
/// `cfg.rate_per_sec`, wait for every verdict, then shut the server
/// down.  Requests are submitted in order; replies are awaited after the
/// last arrival, so slow replicas delay accounting, never arrivals.
pub fn run_open_loop(
    server: StreamingServer,
    samples: &[Sample],
    cfg: &OpenLoopCfg,
) -> OpenLoopReport {
    assert!(cfg.rate_per_sec > 0.0, "open loop needs a positive arrival rate");
    assert!(!samples.is_empty(), "open loop needs at least one request");
    let replicas = server.replicas();
    let policy = server.policy_name();
    let mut rng = Rng::new(cfg.seed);
    let mut receivers = Vec::with_capacity(samples.len());
    let mut due = Duration::ZERO;
    let t0 = Instant::now();
    for s in samples {
        // Poisson process: exponential inter-arrival gaps at the target
        // rate.  1 - f64() keeps the argument in (0, 1] so ln is finite.
        let gap = -(1.0 - rng.f64()).ln() / cfg.rate_per_sec;
        due += Duration::from_secs_f64(gap);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            if !wait.is_zero() {
                thread::sleep(wait);
            }
        }
        receivers.push(server.submit(s));
    }
    let (replies, dropped) = drain_replies(receivers);
    let wall = t0.elapsed();
    let respawns = server.respawns();
    let (lifetime, _) = server.shutdown();
    // split explicit overload refusals from real verdicts (arrival order
    // is preserved — `replies` follows submission order)
    let served: Vec<&Reply> = replies.iter().filter(|r| !r.shed).collect();
    let shed = replies.len() - served.len();
    assert!(lifetime >= served.len() as u64, "replicas lost requests");
    if served.is_empty() {
        // every reply channel disconnected or shed: report the counts
        // with zeroed latency stats instead of dividing by nothing
        return OpenLoopReport {
            offered: samples.len(),
            served: 0,
            dropped,
            shed,
            respawns,
            wall,
            offered_rate: cfg.rate_per_sec,
            achieved_rate: 0.0,
            mean_window: Duration::ZERO,
            p50_window: Duration::ZERO,
            p99_window: Duration::ZERO,
            max_window: Duration::ZERO,
            mean_queue_delay: Duration::ZERO,
            p99_queue_delay: Duration::ZERO,
            mean_service: Duration::ZERO,
            p99_service: Duration::ZERO,
            replicas,
            policy,
            tail_p99_window: Duration::ZERO,
            window_samples: Vec::new(),
        };
    }

    let d = |s: f64| Duration::from_secs_f64(s.max(0.0));
    // post-recovery tail: p99 over the second half of served requests in
    // arrival order (a kill/respawn arm's recovered steady state)
    let mut tail: Vec<f64> = served[served.len() / 2..]
        .iter()
        .map(|r| r.latency.as_secs_f64())
        .collect();
    tail.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tail_p99_window = d(percentile(&tail, 0.99));

    let mut windows: Vec<f64> = served.iter().map(|r| r.latency.as_secs_f64()).collect();
    let mut queue: Vec<f64> =
        served.iter().map(|r| r.queue_delay.as_secs_f64()).collect();
    let mut service: Vec<f64> =
        served.iter().map(|r| r.service_time().as_secs_f64()).collect();
    for v in [&mut windows, &mut queue, &mut service] {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;

    OpenLoopReport {
        offered: samples.len(),
        served: served.len() as u64,
        dropped,
        shed,
        respawns,
        wall,
        offered_rate: cfg.rate_per_sec,
        achieved_rate: served.len() as f64 / wall.as_secs_f64().max(1e-12),
        mean_window: d(mean(&windows)),
        p50_window: d(percentile(&windows, 0.50)),
        p99_window: d(percentile(&windows, 0.99)),
        max_window: d(*windows.last().unwrap()),
        mean_queue_delay: d(mean(&queue)),
        p99_queue_delay: d(percentile(&queue, 0.99)),
        mean_service: d(mean(&service)),
        p99_service: d(percentile(&service, 0.99)),
        replicas,
        policy,
        tail_p99_window,
        window_samples: windows,
    }
}

/// Await every reply channel in submission order.  A disconnected
/// channel (the replica dropped the sender before answering — a session
/// shutdown racing the drain, or an injected reply-sever fault) counts
/// that request as dropped instead of aborting the whole open-loop run;
/// so does a reply that fails to arrive within a generous deadline (an
/// unsupervised replica died with the request queued — without the
/// timeout the drain would block forever).
fn drain_replies(receivers: Vec<mpsc::Receiver<Reply>>) -> (Vec<Reply>, usize) {
    let mut dropped = 0usize;
    let replies = receivers
        .into_iter()
        .filter_map(|rx| match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(r) => Some(r),
            Err(_) => {
                dropped += 1;
                None
            }
        })
        .collect();
    (replies, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::serve::session::ServeSession;
    use crate::util::prng::Rng as TestRng;

    #[test]
    fn open_loop_drains_every_request() {
        let ds = generate(&DatasetCfg {
            n_normal: 40,
            n_attack: 10,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 8,
        });
        let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut TestRng::new(2));
        let server = ServeSession::from_engine(engine).replicas(2).start();
        let cfg = OpenLoopCfg { rate_per_sec: 4000.0, seed: 3 };
        let report = run_open_loop(server, &ds.samples[..30], &cfg);
        assert_eq!(report.offered, 30);
        assert_eq!(report.served, 30);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.respawns, 0);
        assert!(report.tail_p99_window <= report.max_window);
        assert_eq!(report.window_samples.len(), 30);
        assert!(report.achieved_rate > 0.0);
        assert!(report.p50_window <= report.p99_window);
        assert!(report.p99_window <= report.max_window);
        // the split re-adds to the window (pointwise svc = window − queue)
        let sum = report.mean_queue_delay + report.mean_service;
        let diff = if sum > report.mean_window {
            sum - report.mean_window
        } else {
            report.mean_window - sum
        };
        assert!(diff < Duration::from_millis(1), "queue/service split drifted: {diff:?}");
    }

    #[test]
    fn dropped_reply_channels_are_counted_not_fatal() {
        // three in-flight requests; the replica serving the second dies
        // (drops its reply sender without answering) — the drain must
        // count it as dropped and keep the other verdicts
        let mk = |prob: f32| Reply {
            prob,
            latency: Duration::from_micros(50),
            queue_delay: Duration::from_micros(10),
            shed: false,
        };
        let (tx1, rx1) = std::sync::mpsc::channel();
        let (tx2, rx2) = std::sync::mpsc::channel::<Reply>();
        let (tx3, rx3) = std::sync::mpsc::channel();
        tx1.send(mk(0.1)).unwrap();
        drop(tx2); // session shutdown raced the drain
        tx3.send(mk(0.9)).unwrap();
        let (replies, dropped) = drain_replies(vec![rx1, rx2, rx3]);
        assert_eq!(dropped, 1);
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[0].prob, 0.1);
        assert_eq!(replies[1].prob, 0.9);

        // all channels dead: everything dropped, nothing served
        let (txa, rxa) = std::sync::mpsc::channel::<Reply>();
        drop(txa);
        let (replies, dropped) = drain_replies(vec![rxa]);
        assert!(replies.is_empty());
        assert_eq!(dropped, 1);
    }
}
