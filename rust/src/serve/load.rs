//! Open-loop load generation: Poisson arrivals at a target rate,
//! submitted WITHOUT waiting for replies — unlike the closed-loop
//! drivers (`run_stream*`), which can never observe queueing because
//! each client has at most one request in flight.
//!
//! This is the measurement the paper's real-time claim actually needs:
//! under industrial streaming load the attacker's undetected window is
//! the end-to-end detection latency *including queueing*, so the report
//! splits every request's window into queue delay (enqueue → pickup)
//! and service time (pickup → verdict) and summarizes the window
//! percentiles under load.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::powersys::dataset::Sample;
use crate::serve::router::policy_static;
use crate::serve::server::{Reply, StreamingServer};
use crate::util::clock::Clock;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::percentile;

/// Open-loop generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoopCfg {
    /// Target Poisson arrival rate (requests per second).
    pub rate_per_sec: f64,
    /// Seed of the (deterministic) arrival process.
    pub seed: u64,
}

/// What an open-loop run measured.
#[derive(Clone, Debug, PartialEq)]
pub struct OpenLoopReport {
    /// Requests the generator offered (== `samples.len()`).
    pub offered: usize,
    /// Requests that came back with a verdict.  Normally every offered
    /// request; see `dropped` and `shed` for the exceptions.
    pub served: u64,
    /// Requests whose reply channel disconnected (or timed out) before a
    /// verdict arrived (a replica dropped the sender — e.g. a session
    /// shutdown racing the drain, or an injected reply-sever fault).
    /// Counted instead of aborting the run; excluded from every latency
    /// statistic.  Distinct from `shed`: a drop is silent loss, a shed
    /// is an explicit immediate refusal.
    pub dropped: usize,
    /// Requests the router refused under overload (`Reply::shed`) —
    /// answered immediately, never queued, excluded from latency stats.
    pub shed: usize,
    /// Replicas the supervisor respawned during the run.
    pub respawns: u64,
    pub wall: Duration,
    /// Configured arrival rate (requests/s).
    pub offered_rate: f64,
    /// `served / wall` — sags below `offered_rate` once queues grow.
    pub achieved_rate: f64,
    /// Attack-window percentiles: end-to-end detection latency under
    /// load (queue delay + service time).
    pub mean_window: Duration,
    pub p50_window: Duration,
    pub p99_window: Duration,
    pub max_window: Duration,
    /// Queueing side of the window (enqueue → batch pickup).
    pub mean_queue_delay: Duration,
    pub p99_queue_delay: Duration,
    /// Compute side of the window (pickup → verdict).
    pub mean_service: Duration,
    pub p99_service: Duration,
    pub replicas: usize,
    pub policy: &'static str,
    /// p99 attack window over the SECOND HALF of served requests in
    /// arrival order — the post-recovery tail a kill/respawn bench arm
    /// compares against its fault-free twin.
    pub tail_p99_window: Duration,
    /// Sorted per-request windows in seconds (for bench arms /
    /// custom percentiles).
    pub window_samples: Vec<f64>,
}

impl OpenLoopReport {
    /// Assemble a report from raw per-request samples **in arrival
    /// order** (windows/queue/service each hold one entry per served,
    /// non-shed request).  This is the single statistics path shared by
    /// the in-process generator and the multi-node one
    /// (`net::run_open_loop_net`), so their percentile discipline —
    /// tail over the second half in arrival order, mean over the sorted
    /// vector — can never drift apart.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        offered: usize,
        dropped: usize,
        shed: usize,
        respawns: u64,
        wall: Duration,
        offered_rate: f64,
        windows_arrival: &[f64],
        queue_arrival: &[f64],
        service_arrival: &[f64],
        replicas: usize,
        policy: &'static str,
    ) -> OpenLoopReport {
        if windows_arrival.is_empty() {
            // every reply channel disconnected or shed: report the counts
            // with zeroed latency stats instead of dividing by nothing
            return OpenLoopReport {
                offered,
                served: 0,
                dropped,
                shed,
                respawns,
                wall,
                offered_rate,
                achieved_rate: 0.0,
                mean_window: Duration::ZERO,
                p50_window: Duration::ZERO,
                p99_window: Duration::ZERO,
                max_window: Duration::ZERO,
                mean_queue_delay: Duration::ZERO,
                p99_queue_delay: Duration::ZERO,
                mean_service: Duration::ZERO,
                p99_service: Duration::ZERO,
                replicas,
                policy,
                tail_p99_window: Duration::ZERO,
                window_samples: Vec::new(),
            };
        }
        let d = |s: f64| Duration::from_secs_f64(s.max(0.0));
        // post-recovery tail: p99 over the second half of served requests
        // in arrival order (a kill/respawn arm's recovered steady state)
        let mut tail: Vec<f64> = windows_arrival[windows_arrival.len() / 2..].to_vec();
        tail.sort_by(|a, b| a.total_cmp(b));
        let tail_p99_window = d(percentile(&tail, 0.99));

        let mut windows = windows_arrival.to_vec();
        let mut queue = queue_arrival.to_vec();
        let mut service = service_arrival.to_vec();
        for v in [&mut windows, &mut queue, &mut service] {
            v.sort_by(|a, b| a.total_cmp(b));
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;

        OpenLoopReport {
            offered,
            served: windows.len() as u64,
            dropped,
            shed,
            respawns,
            wall,
            offered_rate,
            achieved_rate: windows.len() as f64 / wall.as_secs_f64().max(1e-12),
            mean_window: d(mean(&windows)),
            p50_window: d(percentile(&windows, 0.50)),
            p99_window: d(percentile(&windows, 0.99)),
            max_window: d(windows.last().copied().unwrap_or(0.0)),
            mean_queue_delay: d(mean(&queue)),
            p99_queue_delay: d(percentile(&queue, 0.99)),
            mean_service: d(mean(&service)),
            p99_service: d(percentile(&service, 0.99)),
            replicas,
            policy,
            tail_p99_window,
            window_samples: windows,
        }
    }

    /// Serialize for cross-node aggregation.  Durations travel as
    /// integer nanoseconds (exact below 2^53); floats rely on the
    /// writer's shortest round-trip form.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let ns = |d: Duration| Json::Num(d.as_nanos() as f64);
        m.insert("offered".into(), Json::Num(self.offered as f64));
        m.insert("served".into(), Json::Num(self.served as f64));
        m.insert("dropped".into(), Json::Num(self.dropped as f64));
        m.insert("shed".into(), Json::Num(self.shed as f64));
        m.insert("respawns".into(), Json::Num(self.respawns as f64));
        m.insert("wall_ns".into(), ns(self.wall));
        m.insert("offered_rate".into(), Json::Num(self.offered_rate));
        m.insert("achieved_rate".into(), Json::Num(self.achieved_rate));
        m.insert("mean_window_ns".into(), ns(self.mean_window));
        m.insert("p50_window_ns".into(), ns(self.p50_window));
        m.insert("p99_window_ns".into(), ns(self.p99_window));
        m.insert("max_window_ns".into(), ns(self.max_window));
        m.insert("mean_queue_delay_ns".into(), ns(self.mean_queue_delay));
        m.insert("p99_queue_delay_ns".into(), ns(self.p99_queue_delay));
        m.insert("mean_service_ns".into(), ns(self.mean_service));
        m.insert("p99_service_ns".into(), ns(self.p99_service));
        m.insert("replicas".into(), Json::Num(self.replicas as f64));
        m.insert("policy".into(), Json::Str(self.policy.to_string()));
        m.insert("tail_p99_window_ns".into(), ns(self.tail_p99_window));
        m.insert(
            "window_samples".into(),
            Json::Arr(self.window_samples.iter().map(|&w| Json::Num(w)).collect()),
        );
        Json::Obj(m)
    }

    /// Parse a report serialized by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> Result<OpenLoopReport> {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).context(format!("missing {k}"));
        let u = |k: &str| j.get(k).and_then(Json::as_u64).context(format!("missing {k}"));
        let zu = |k: &str| j.get(k).and_then(Json::as_usize).context(format!("missing {k}"));
        let dur = |k: &str| u(k).map(Duration::from_nanos);
        let windows = j
            .get("window_samples")
            .and_then(Json::as_arr)
            .context("missing window_samples")?
            .iter()
            .map(|w| w.as_f64().context("non-numeric window sample"))
            .collect::<Result<Vec<f64>>>()?;
        Ok(OpenLoopReport {
            offered: zu("offered")?,
            served: u("served")?,
            dropped: zu("dropped")?,
            shed: zu("shed")?,
            respawns: u("respawns")?,
            wall: dur("wall_ns")?,
            offered_rate: f("offered_rate")?,
            achieved_rate: f("achieved_rate")?,
            mean_window: dur("mean_window_ns")?,
            p50_window: dur("p50_window_ns")?,
            p99_window: dur("p99_window_ns")?,
            max_window: dur("max_window_ns")?,
            mean_queue_delay: dur("mean_queue_delay_ns")?,
            p99_queue_delay: dur("p99_queue_delay_ns")?,
            mean_service: dur("mean_service_ns")?,
            p99_service: dur("p99_service_ns")?,
            replicas: zu("replicas")?,
            policy: policy_static(
                j.get("policy").and_then(Json::as_str).context("missing policy")?,
            ),
            tail_p99_window: dur("tail_p99_window_ns")?,
            window_samples: windows,
        })
    }
}

/// Drive `samples` through the server as an open-loop Poisson stream at
/// `cfg.rate_per_sec`, wait for every verdict, then shut the server
/// down.  Requests are submitted in order; replies are awaited after the
/// last arrival, so slow replicas delay accounting, never arrivals.
pub fn run_open_loop(
    server: StreamingServer,
    samples: &[Sample],
    cfg: &OpenLoopCfg,
) -> OpenLoopReport {
    run_open_loop_clocked(server, samples, cfg, &Clock::real())
}

/// `run_open_loop` with an injected clock: the pacing and wall-time
/// accounting read `clock` instead of the wall directly, so tests can
/// pin the measured wall (and therefore `achieved_rate`) exactly.  With
/// a manual clock the generator never sleeps — every arrival whose due
/// time has "passed" submits immediately.
pub fn run_open_loop_clocked(
    server: StreamingServer,
    samples: &[Sample],
    cfg: &OpenLoopCfg,
    clock: &Clock,
) -> OpenLoopReport {
    assert!(cfg.rate_per_sec > 0.0, "open loop needs a positive arrival rate");
    assert!(!samples.is_empty(), "open loop needs at least one request");
    let replicas = server.replicas();
    let policy = server.policy_name();
    let mut rng = Rng::new(cfg.seed);
    let mut receivers = Vec::with_capacity(samples.len());
    let mut due = 0.0f64;
    let t0 = clock.now();
    for s in samples {
        // Poisson process: exponential inter-arrival gaps at the target
        // rate.  1 - f64() keeps the argument in (0, 1] so ln is finite.
        let gap = -(1.0 - rng.f64()).ln() / cfg.rate_per_sec;
        due += gap;
        let wait = due - (clock.now() - t0);
        if wait > 0.0 {
            thread::sleep(Duration::from_secs_f64(wait));
        }
        receivers.push(server.submit(s));
    }
    let (replies, dropped) = drain_replies(receivers);
    let wall = Duration::from_secs_f64((clock.now() - t0).max(1e-12));
    let respawns = server.respawns();
    let (lifetime, _) = server.shutdown();
    // split explicit overload refusals from real verdicts (arrival order
    // is preserved — `replies` follows submission order)
    let served: Vec<&Reply> = replies.iter().filter(|r| !r.shed).collect();
    let shed = replies.len() - served.len();
    assert!(lifetime >= served.len() as u64, "replicas lost requests");
    let windows: Vec<f64> = served.iter().map(|r| r.latency.as_secs_f64()).collect();
    let queue: Vec<f64> = served.iter().map(|r| r.queue_delay.as_secs_f64()).collect();
    let service: Vec<f64> =
        served.iter().map(|r| r.service_time().as_secs_f64()).collect();
    OpenLoopReport::from_parts(
        samples.len(),
        dropped,
        shed,
        respawns,
        wall,
        cfg.rate_per_sec,
        &windows,
        &queue,
        &service,
        replicas,
        policy,
    )
}

/// Await every reply channel in submission order.  A disconnected
/// channel (the replica dropped the sender before answering — a session
/// shutdown racing the drain, or an injected reply-sever fault) counts
/// that request as dropped instead of aborting the whole open-loop run;
/// so does a reply that fails to arrive within a generous deadline (an
/// unsupervised replica died with the request queued — without the
/// timeout the drain would block forever).
fn drain_replies(receivers: Vec<mpsc::Receiver<Reply>>) -> (Vec<Reply>, usize) {
    let mut dropped = 0usize;
    let replies = receivers
        .into_iter()
        .filter_map(|rx| match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(r) => Some(r),
            Err(_) => {
                dropped += 1;
                None
            }
        })
        .collect();
    (replies, dropped)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{EngineCfg, NativeDlrm};
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::serve::session::ServeSession;
    use crate::util::prng::Rng as TestRng;

    #[test]
    fn open_loop_drains_every_request() {
        let ds = generate(&DatasetCfg {
            n_normal: 40,
            n_attack: 10,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 8,
        });
        let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut TestRng::new(2));
        let server = ServeSession::from_engine(engine).replicas(2).start();
        let cfg = OpenLoopCfg { rate_per_sec: 4000.0, seed: 3 };
        let report = run_open_loop(server, &ds.samples[..30], &cfg);
        assert_eq!(report.offered, 30);
        assert_eq!(report.served, 30);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.shed, 0);
        assert_eq!(report.respawns, 0);
        assert!(report.tail_p99_window <= report.max_window);
        assert_eq!(report.window_samples.len(), 30);
        assert!(report.achieved_rate > 0.0);
        assert!(report.p50_window <= report.p99_window);
        assert!(report.p99_window <= report.max_window);
        // the split re-adds to the window (pointwise svc = window − queue)
        let sum = report.mean_queue_delay + report.mean_service;
        let diff = if sum > report.mean_window {
            sum - report.mean_window
        } else {
            report.mean_window - sum
        };
        assert!(diff < Duration::from_millis(1), "queue/service split drifted: {diff:?}");
    }

    #[test]
    fn open_loop_with_manual_clock_is_wall_clock_free() {
        // the generator's pacing and wall accounting go through the
        // injected Clock (lint rule D2): with a manual clock that never
        // advances, the measured wall is exactly zero no matter how
        // long the replicas really took, and achieved_rate is a pure
        // function of the served count
        let ds = generate(&DatasetCfg {
            n_normal: 20,
            n_attack: 5,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 8,
        });
        let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut TestRng::new(2));
        let server = ServeSession::from_engine(engine).replicas(2).start();
        // high rate keeps the (real) sleeps the manual clock induces
        // far below a millisecond in total
        let cfg = OpenLoopCfg { rate_per_sec: 500_000.0, seed: 3 };
        let clock = Clock::manual();
        let report = run_open_loop_clocked(server, &ds.samples[..16], &cfg, &clock);
        assert_eq!(report.offered, 16);
        assert_eq!(report.served + report.shed as u64 + report.dropped as u64, 16);
        assert_eq!(report.wall, Duration::ZERO, "wall leaked real time");
        let expect_rate = report.served as f64 / 1e-12;
        assert_eq!(report.achieved_rate, expect_rate);
    }

    #[test]
    fn dropped_reply_channels_are_counted_not_fatal() {
        // three in-flight requests; the replica serving the second dies
        // (drops its reply sender without answering) — the drain must
        // count it as dropped and keep the other verdicts
        let mk = |prob: f32| Reply {
            prob,
            latency: Duration::from_micros(50),
            queue_delay: Duration::from_micros(10),
            shed: false,
        };
        let (tx1, rx1) = std::sync::mpsc::channel();
        let (tx2, rx2) = std::sync::mpsc::channel::<Reply>();
        let (tx3, rx3) = std::sync::mpsc::channel();
        tx1.send(mk(0.1)).unwrap();
        drop(tx2); // session shutdown raced the drain
        tx3.send(mk(0.9)).unwrap();
        let (replies, dropped) = drain_replies(vec![rx1, rx2, rx3]);
        assert_eq!(dropped, 1);
        assert_eq!(replies.len(), 2);
        assert_eq!(replies[0].prob, 0.1);
        assert_eq!(replies[1].prob, 0.9);

        // all channels dead: everything dropped, nothing served
        let (txa, rxa) = std::sync::mpsc::channel::<Reply>();
        drop(txa);
        let (replies, dropped) = drain_replies(vec![rxa]);
        assert!(replies.is_empty());
        assert_eq!(dropped, 1);
    }

    #[test]
    fn open_loop_report_round_trips_through_json() {
        let windows = [0.0011, 0.0007, 0.0042, 0.0009, 0.0013];
        let queue = [0.0002, 0.0001, 0.0031, 0.0001, 0.0002];
        let service = [0.0009, 0.0006, 0.0011, 0.0008, 0.0011];
        let report = OpenLoopReport::from_parts(
            7,
            1,
            1,
            2,
            Duration::from_micros(8_765_432),
            3000.0,
            &windows,
            &queue,
            &service,
            3,
            "plan_affinity",
        );
        let text = report.to_json().to_string();
        let back = OpenLoopReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(report, back, "report drifted through JSON");
        // a second trip is textually stable
        assert_eq!(text, back.to_json().to_string());

        // the zero-served degenerate form round-trips too
        let empty = OpenLoopReport::from_parts(
            4,
            4,
            0,
            0,
            Duration::from_millis(12),
            100.0,
            &[],
            &[],
            &[],
            1,
            "round_robin",
        );
        let back =
            OpenLoopReport::from_json(&Json::parse(&empty.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(empty, back);

        // unknown policies degrade to "unknown" instead of failing
        let mut j = report.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("policy".into(), Json::Str("fancy_future_policy".into()));
        }
        assert_eq!(OpenLoopReport::from_json(&j).unwrap().policy, "unknown");
    }
}
