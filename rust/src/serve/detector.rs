//! The detection head: wraps a trained model (native engine) and issues
//! verdicts with attack-window accounting (the paper's motivation: every
//! ms of detection latency is attacker opportunity).

use std::time::{Duration, Instant};

use crate::access::{AccessPlanner, BatchPlan};
use crate::coordinator::engine::NativeDlrm;
use crate::data::ctr::Batch;
use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};

/// One detection outcome.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub attack_probability: f32,
    pub is_attack: bool,
    /// End-to-end handling latency of this request.
    pub latency: Duration,
    /// The measurement vector contained NaN/inf channels that were
    /// clamped before scoring.  A poisoned measurement is itself a
    /// strong tamper signal — the flag lets operators alarm on it even
    /// when the clamped probability stays below threshold.
    pub poisoned: bool,
}

/// `Clone` so a trained detector can be replicated across serving shards
/// (`StreamingServer::start_sharded`) without retraining.  Each clone
/// carries its own batch + access-plan scratch, so every serving replica
/// plans requests allocation-free with zero cross-replica sharing.
#[derive(Clone)]
pub struct Detector {
    pub engine: NativeDlrm,
    pub threshold: f32,
    /// Lifetime count of samples whose dense measurements carried
    /// NaN/inf channels (clamped to 0.0 before scoring, never propagated
    /// into the MLP).
    pub poisoned: u64,
    scratch: Batch,
    planner: AccessPlanner,
    plan: BatchPlan,
}

impl Detector {
    pub fn new(engine: NativeDlrm, threshold: f32) -> Detector {
        let planner = AccessPlanner::for_engine_cfg(&engine.cfg);
        Detector::with_planner(engine, threshold, planner)
    }

    /// Serve through a SPECIFIC planner — required when the engine was
    /// trained under a profiled or online-refreshed bijection: the
    /// learned embedding rows are only consistent with that remap, so
    /// serving must read back through it.  The planner is frozen here
    /// (scoring never advances online-reorder state); its layout policy
    /// (tiling / fusion) carries over to the serving plans.
    pub fn with_planner(engine: NativeDlrm, threshold: f32, planner: AccessPlanner) -> Detector {
        Detector {
            engine,
            threshold,
            poisoned: 0,
            scratch: Batch::default(),
            planner,
            plan: BatchPlan::default(),
        }
    }

    /// Append one sample's dense measurements to the scratch batch,
    /// clamping non-finite channels to 0.0 instead of letting a single
    /// poisoned sensor reading propagate NaN through the MLP into a
    /// garbage probability (and, batched, into OTHER requests' scores).
    /// Returns whether anything had to be clamped.  Finite inputs are
    /// copied verbatim — the fault-free path is bit-identical.
    fn push_dense_sanitized(&mut self, dense: &[f32]) -> bool {
        let mut dirty = false;
        for &v in dense {
            if v.is_finite() {
                self.scratch.dense.push(v);
            } else {
                dirty = true;
                self.scratch.dense.push(0.0);
            }
        }
        if dirty {
            self.poisoned += 1;
        }
        dirty
    }

    /// Run the assembled scratch batch through the planned predict path.
    /// Serving is read-only traffic: plans are built FROZEN (current
    /// bijections, no online observation) so replicas never drift apart.
    fn predict_scratch(&mut self) -> Vec<f32> {
        self.planner.plan_frozen_into(&self.scratch, &mut self.plan);
        self.engine.predict_planned(&self.scratch, &self.plan)
    }

    /// Score one sample (batch-1 streaming path).
    pub fn score(&mut self, sample: &Sample) -> f32 {
        self.scratch.dense.clear();
        self.push_dense_sanitized(&sample.dense);
        self.scratch.sparse.clear();
        self.scratch.sparse.extend_from_slice(&sample.sparse);
        self.scratch.labels.clear();
        self.scratch.labels.push(0.0);
        self.scratch.batch_size = 1;
        self.predict_scratch()[0]
    }

    /// Score a micro-batch of samples at once (router path).
    pub fn score_batch(&mut self, samples: &[&Sample]) -> Vec<f32> {
        let b = samples.len();
        self.scratch.dense.clear();
        self.scratch.sparse.clear();
        self.scratch.labels.clear();
        for s in samples {
            let dense = s.dense;
            self.push_dense_sanitized(&dense);
            self.scratch.sparse.extend_from_slice(&s.sparse);
            self.scratch.labels.push(0.0);
        }
        debug_assert_eq!(self.scratch.dense.len(), b * N_DENSE);
        debug_assert_eq!(self.scratch.sparse.len(), b * N_SPARSE);
        self.scratch.batch_size = b;
        self.predict_scratch()
    }

    /// Score one sample and measure the handling latency here — the
    /// pre-redesign signature took the latency as a caller-supplied
    /// argument, which let drivers stamp verdicts with unrelated clocks.
    /// Server-side queueing is accounted separately by the serving path
    /// ([`Reply`](crate::serve::Reply)'s queue-delay/service split).
    pub fn verdict(&mut self, sample: &Sample) -> Verdict {
        // lint:allow(D2) verdict latency stamps the real compute; nothing asserts its value
        let t0 = Instant::now();
        let before = self.poisoned;
        let p = self.score(sample);
        Verdict {
            attack_probability: p,
            is_attack: p > self.threshold,
            latency: t0.elapsed(),
            poisoned: self.poisoned > before,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    #[test]
    fn scores_in_unit_interval_and_batch_matches_single() {
        let ds = generate(&DatasetCfg {
            n_normal: 40,
            n_attack: 10,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 1,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(2));
        let mut det = Detector::new(engine, 0.5);
        let singles: Vec<f32> = ds.samples[..8].iter().map(|s| {
            let p = det.score(s);
            assert!((0.0..=1.0).contains(&p));
            p
        }).collect();
        let refs: Vec<&Sample> = ds.samples[..8].iter().collect();
        let batched = det.score_batch(&refs);
        for (a, b) in singles.iter().zip(&batched) {
            assert!((a - b).abs() < 1e-5, "batch/single mismatch {a} vs {b}");
        }
    }

    #[test]
    fn poisoned_samples_are_clamped_and_flagged_not_propagated() {
        let ds = generate(&DatasetCfg {
            n_normal: 20,
            n_attack: 5,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 5,
            noise_std: 0.005,
            seed: 9,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(4));
        let mut det = Detector::new(engine, 0.5);

        // a NaN/inf measurement vector must still yield a finite verdict
        let mut poisoned = ds.samples[0].clone();
        poisoned.dense[0] = f32::NAN;
        poisoned.dense[1] = f32::INFINITY;
        poisoned.dense[2] = f32::NEG_INFINITY;
        let v = det.verdict(&poisoned);
        assert!(v.attack_probability.is_finite(), "NaN leaked through the MLP");
        assert!((0.0..=1.0).contains(&v.attack_probability));
        assert!(v.poisoned, "clamped sample must be flagged");
        assert_eq!(det.poisoned, 1);

        // the clamp is equivalent to zeroing the poisoned channels…
        let mut zeroed = ds.samples[0].clone();
        zeroed.dense[0] = 0.0;
        zeroed.dense[1] = 0.0;
        zeroed.dense[2] = 0.0;
        let pz = det.score(&zeroed);
        let pp = det.score(&poisoned);
        assert_eq!(pp.to_bits(), pz.to_bits(), "clamp must equal explicit zeroing");

        // …and a clean sample is copied verbatim, unflagged
        let before = det.poisoned;
        let v = det.verdict(&ds.samples[1]);
        assert!(!v.poisoned);
        assert_eq!(det.poisoned, before);

        // batched scoring: the poisoned row must not corrupt its peers
        let clean = det.score(&ds.samples[1]);
        let refs: Vec<&Sample> = vec![&poisoned, &ds.samples[1]];
        let batched = det.score_batch(&refs);
        assert!(batched.iter().all(|p| p.is_finite()));
        assert!((batched[1] - clean).abs() < 1e-5, "poisoned row smeared its neighbor");
    }

    #[test]
    fn verdict_measures_its_own_latency() {
        let ds = generate(&DatasetCfg {
            n_normal: 20,
            n_attack: 5,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 5,
            noise_std: 0.005,
            seed: 3,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(4));
        let mut det = Detector::new(engine, 0.5);
        let v = det.verdict(&ds.samples[0]);
        assert!((0.0..=1.0).contains(&v.attack_probability));
        assert_eq!(v.is_attack, v.attack_probability > 0.5);
        assert!(v.latency > Duration::ZERO, "latency must be measured, not supplied");
    }
}
