//! The detection head: wraps a trained model (native engine) and issues
//! verdicts with attack-window accounting (the paper's motivation: every
//! ms of detection latency is attacker opportunity).

use std::time::{Duration, Instant};

use crate::access::{AccessPlanner, BatchPlan};
use crate::coordinator::engine::NativeDlrm;
use crate::data::ctr::Batch;
use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};

/// One detection outcome.
#[derive(Clone, Debug)]
pub struct Verdict {
    pub attack_probability: f32,
    pub is_attack: bool,
    /// End-to-end handling latency of this request.
    pub latency: Duration,
}

/// `Clone` so a trained detector can be replicated across serving shards
/// (`StreamingServer::start_sharded`) without retraining.  Each clone
/// carries its own batch + access-plan scratch, so every serving replica
/// plans requests allocation-free with zero cross-replica sharing.
#[derive(Clone)]
pub struct Detector {
    pub engine: NativeDlrm,
    pub threshold: f32,
    scratch: Batch,
    planner: AccessPlanner,
    plan: BatchPlan,
}

impl Detector {
    pub fn new(engine: NativeDlrm, threshold: f32) -> Detector {
        let planner = AccessPlanner::for_engine_cfg(&engine.cfg);
        Detector::with_planner(engine, threshold, planner)
    }

    /// Serve through a SPECIFIC planner — required when the engine was
    /// trained under a profiled or online-refreshed bijection: the
    /// learned embedding rows are only consistent with that remap, so
    /// serving must read back through it.  The planner is frozen here
    /// (scoring never advances online-reorder state); its layout policy
    /// (tiling / fusion) carries over to the serving plans.
    pub fn with_planner(engine: NativeDlrm, threshold: f32, planner: AccessPlanner) -> Detector {
        Detector {
            engine,
            threshold,
            scratch: Batch::default(),
            planner,
            plan: BatchPlan::default(),
        }
    }

    /// Run the assembled scratch batch through the planned predict path.
    /// Serving is read-only traffic: plans are built FROZEN (current
    /// bijections, no online observation) so replicas never drift apart.
    fn predict_scratch(&mut self) -> Vec<f32> {
        self.planner.plan_frozen_into(&self.scratch, &mut self.plan);
        self.engine.predict_planned(&self.scratch, &self.plan)
    }

    /// Score one sample (batch-1 streaming path).
    pub fn score(&mut self, sample: &Sample) -> f32 {
        self.scratch.dense.clear();
        self.scratch.dense.extend_from_slice(&sample.dense);
        self.scratch.sparse.clear();
        self.scratch.sparse.extend_from_slice(&sample.sparse);
        self.scratch.labels.clear();
        self.scratch.labels.push(0.0);
        self.scratch.batch_size = 1;
        self.predict_scratch()[0]
    }

    /// Score a micro-batch of samples at once (router path).
    pub fn score_batch(&mut self, samples: &[&Sample]) -> Vec<f32> {
        let b = samples.len();
        self.scratch.dense.clear();
        self.scratch.sparse.clear();
        self.scratch.labels.clear();
        for s in samples {
            self.scratch.dense.extend_from_slice(&s.dense);
            self.scratch.sparse.extend_from_slice(&s.sparse);
            self.scratch.labels.push(0.0);
        }
        debug_assert_eq!(self.scratch.dense.len(), b * N_DENSE);
        debug_assert_eq!(self.scratch.sparse.len(), b * N_SPARSE);
        self.scratch.batch_size = b;
        self.predict_scratch()
    }

    /// Score one sample and measure the handling latency here — the
    /// pre-redesign signature took the latency as a caller-supplied
    /// argument, which let drivers stamp verdicts with unrelated clocks.
    /// Server-side queueing is accounted separately by the serving path
    /// ([`Reply`](crate::serve::Reply)'s queue-delay/service split).
    pub fn verdict(&mut self, sample: &Sample) -> Verdict {
        let t0 = Instant::now();
        let p = self.score(sample);
        Verdict {
            attack_probability: p,
            is_attack: p > self.threshold,
            latency: t0.elapsed(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    #[test]
    fn scores_in_unit_interval_and_batch_matches_single() {
        let ds = generate(&DatasetCfg {
            n_normal: 40,
            n_attack: 10,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 1,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(2));
        let mut det = Detector::new(engine, 0.5);
        let singles: Vec<f32> = ds.samples[..8].iter().map(|s| {
            let p = det.score(s);
            assert!((0.0..=1.0).contains(&p));
            p
        }).collect();
        let refs: Vec<&Sample> = ds.samples[..8].iter().collect();
        let batched = det.score_batch(&refs);
        for (a, b) in singles.iter().zip(&batched) {
            assert!((a - b).abs() < 1e-5, "batch/single mismatch {a} vs {b}");
        }
    }

    #[test]
    fn verdict_measures_its_own_latency() {
        let ds = generate(&DatasetCfg {
            n_normal: 20,
            n_attack: 5,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 5,
            noise_std: 0.005,
            seed: 3,
        });
        let cfg = EngineCfg::ieee118(1.0 / 2000.0);
        let engine = NativeDlrm::new(cfg, &mut Rng::new(4));
        let mut det = Detector::new(engine, 0.5);
        let v = det.verdict(&ds.samples[0]);
        assert!((0.0..=1.0).contains(&v.attack_probability));
        assert_eq!(v.is_attack, v.attack_probability > 0.5);
        assert!(v.latency > Duration::ZERO, "latency must be measured, not supplied");
    }
}
