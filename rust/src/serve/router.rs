//! Pluggable request routing for the serving stack: a [`RoutePolicy`]
//! decides which detector replica serves each request.
//!
//! Three built-in policies:
//!
//! * [`RoundRobin`] — the pre-redesign behavior: an atomic cursor cycling
//!   over replicas, blind to queue state and index locality.
//! * [`LeastQueued`] — per-replica queue-depth gauges ([`QueueDepths`]:
//!   incremented at dispatch, decremented when the replica finishes a
//!   request); each request goes to the shallowest queue, with a rotating
//!   scan start so ties don't pile onto replica 0.
//! * [`PlanAffinity`] — plan-driven shard routing (the ROADMAP item): a
//!   request's compressed sparse indices are pushed through the planner's
//!   bijections and TT prefix map ([`AffinityMap`]) — the exact quantity
//!   `TtPlan` groups rows by — and the mixed key picks the replica.
//!   Requests sharing hot prefixes keep landing on the same replica, so
//!   that replica's plan scratch, reuse-buffer partial products and
//!   tiled row sets (`TtPlan::tile_slots`) stay warm.
//!
//! Replicas are clones of one trained detector, so the policy can NEVER
//! change a verdict — only queueing and cache behavior.  Pinned by
//! `tests/serve_equivalence.rs`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::access::AffinityMap;
use crate::powersys::dataset::Sample;

/// Per-replica in-flight request gauges, shared between the server's
/// dispatch side (enter) and the replica workers (leave).  Next to the
/// depth gauges sit the fault-tolerance signals the supervisor reads:
/// a per-replica heartbeat counter (bumped every batch pickup) and a
/// liveness bit (cleared when a replica worker unwinds, restored on
/// respawn).  Policies consult the liveness bits so a dead replica stops
/// receiving traffic the instant it dies, not after its respawn.
pub struct QueueDepths {
    depths: Vec<AtomicUsize>,
    beats: Vec<AtomicU64>,
    live: Vec<AtomicBool>,
}

impl QueueDepths {
    pub fn new(replicas: usize) -> QueueDepths {
        QueueDepths {
            depths: (0..replicas).map(|_| AtomicUsize::new(0)).collect(),
            beats: (0..replicas).map(|_| AtomicU64::new(0)).collect(),
            live: (0..replicas).map(|_| AtomicBool::new(true)).collect(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.depths.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.depths.is_empty()
    }

    /// Current in-flight request count of replica `i`.
    #[inline]
    pub fn depth(&self, i: usize) -> usize {
        self.depths[i].load(Ordering::Relaxed)
    }

    /// A request was dispatched to replica `i`.
    #[inline]
    pub fn enter(&self, i: usize) {
        self.depths[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Replica `i` finished a request.
    #[inline]
    pub fn leave(&self, i: usize) {
        self.depths[i].fetch_sub(1, Ordering::Relaxed);
    }

    /// Replica `i` proves progress (called once per batch pickup).
    #[inline]
    pub fn beat(&self, i: usize) {
        self.beats[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Heartbeat counter of replica `i` — the supervisor compares
    /// successive readings to detect a hung worker.
    #[inline]
    pub fn beats(&self, i: usize) -> u64 {
        self.beats[i].load(Ordering::Relaxed)
    }

    /// Is replica `i` currently believed alive?
    #[inline]
    pub fn alive(&self, i: usize) -> bool {
        self.live[i].load(Ordering::Relaxed)
    }

    /// Flip replica `i`'s liveness (worker unwind → false, respawn →
    /// true).
    #[inline]
    pub fn set_alive(&self, i: usize, alive: bool) {
        self.live[i].store(alive, Ordering::Relaxed);
    }

    /// Number of replicas currently marked alive.
    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|a| a.load(Ordering::Relaxed)).count()
    }

    /// First alive replica at or cyclically after `start`; falls back to
    /// `start` itself when every replica is marked dead (the queue still
    /// exists, so the request waits for the supervisor's respawn instead
    /// of being lost).  With a full live-set this is the identity map —
    /// policies built on it are bit-identical to their pre-fault-layer
    /// routing.
    #[inline]
    pub fn first_alive_from(&self, start: usize) -> usize {
        let n = self.depths.len();
        for k in 0..n {
            let i = (start + k) % n;
            if self.alive(i) {
                return i;
            }
        }
        start
    }
}

/// A routing decision per request.  Implementations must be `Sync`:
/// `route` is called concurrently from every closed-loop client thread.
pub trait RoutePolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Pick the replica (`< depths.len()`) that serves `sample`.
    fn route(&self, sample: &Sample, depths: &QueueDepths) -> usize;
}

/// Blind cyclic dispatch (the legacy `StreamingServer` behavior).
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&self, _sample: &Sample, depths: &QueueDepths) -> usize {
        let pick = self.next.fetch_add(1, Ordering::Relaxed) % depths.len();
        depths.first_alive_from(pick)
    }
}

/// Route to the replica with the fewest in-flight requests.  The scan
/// start rotates so equal-depth replicas share the load instead of the
/// lowest index absorbing every tie.
#[derive(Default)]
pub struct LeastQueued {
    cursor: AtomicUsize,
}

impl LeastQueued {
    pub fn new() -> LeastQueued {
        LeastQueued::default()
    }
}

impl RoutePolicy for LeastQueued {
    fn name(&self) -> &'static str {
        "least_queued"
    }

    fn route(&self, _sample: &Sample, depths: &QueueDepths) -> usize {
        let n = depths.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        // Scan only the live-set; with every replica alive this reduces
        // exactly to the pre-fault-layer shallowest-queue scan.
        let mut best: Option<(usize, usize)> = None;
        for k in 0..n {
            let i = (start + k) % n;
            if !depths.alive(i) {
                continue;
            }
            let d = depths.depth(i);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((i, d));
            }
        }
        match best {
            Some((i, _)) => i,
            None => depths.first_alive_from(start),
        }
    }
}

/// Plan-driven shard routing: hash the request's post-bijection TT
/// prefixes ([`AffinityMap::key`]) onto a replica.  Stateless and
/// deterministic — the same hot rows always land on the same replica,
/// whose plan scratch and embedding tiles are already warm.
pub struct PlanAffinity {
    map: AffinityMap,
}

impl PlanAffinity {
    pub fn new(map: AffinityMap) -> PlanAffinity {
        PlanAffinity { map }
    }
}

impl RoutePolicy for PlanAffinity {
    fn name(&self) -> &'static str {
        "plan_affinity"
    }

    fn route(&self, sample: &Sample, depths: &QueueDepths) -> usize {
        let pick = (self.map.key(&sample.sparse) % depths.len() as u64) as usize;
        // Affinity is best-effort under faults: a dead owner's keys walk
        // forward to the next live replica and snap back on respawn.
        depths.first_alive_from(pick)
    }
}

/// Route-policy selector for config / CLI (`[serve] policy = "…"`,
/// `--policy …`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastQueued,
    PlanAffinity,
}

impl Policy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round_robin",
            Policy::LeastQueued => "least_queued",
            Policy::PlanAffinity => "plan_affinity",
        }
    }

    /// Parse a policy name; accepts `-` or `_` separators.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        match s.trim().to_ascii_lowercase().replace('-', "_").as_str() {
            "round_robin" | "rr" => Ok(Policy::RoundRobin),
            "least_queued" | "lq" => Ok(Policy::LeastQueued),
            "plan_affinity" | "pa" => Ok(Policy::PlanAffinity),
            other => anyhow::bail!(
                "unknown route policy '{other}' \
                 (expected round_robin | least_queued | plan_affinity)"
            ),
        }
    }
}

impl std::str::FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Policy> {
        Policy::parse(s)
    }
}

/// Intern a policy name parsed from a serialized report back to the
/// `&'static str` the report structs carry.  Unknown names collapse to
/// `"unknown"` rather than failing the parse — a router aggregating
/// reports from a newer node should keep the numbers.
pub(crate) fn policy_static(name: &str) -> &'static str {
    match name {
        "round_robin" => "round_robin",
        "least_queued" => "least_queued",
        "plan_affinity" => "plan_affinity",
        "ring_affinity" => "ring_affinity",
        _ => "unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{N_DENSE, N_SPARSE};

    fn sample(seed: u64) -> Sample {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut sparse = [0u64; N_SPARSE];
        for v in sparse.iter_mut() {
            *v = rng.below(100);
        }
        Sample { dense: [0.0; N_DENSE], sparse, label: 0.0, attack_kind: None }
    }

    #[test]
    fn policy_parse_round_trips_and_rejects_garbage() {
        for p in [Policy::RoundRobin, Policy::LeastQueued, Policy::PlanAffinity] {
            assert_eq!(Policy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(Policy::parse("plan-affinity").unwrap(), Policy::PlanAffinity);
        assert_eq!(Policy::parse("RR").unwrap(), Policy::RoundRobin);
        assert!(Policy::parse("random").is_err());
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let d = QueueDepths::new(3);
        let rr = RoundRobin::new();
        let s = sample(1);
        let picks: Vec<usize> = (0..6).map(|_| rr.route(&s, &d)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_queued_prefers_shallow_queues() {
        let d = QueueDepths::new(3);
        d.enter(0);
        d.enter(0);
        d.enter(1);
        let lq = LeastQueued::new();
        let s = sample(2);
        // replica 2 is empty: every route must pick it until it fills
        for _ in 0..4 {
            assert_eq!(lq.route(&s, &d), 2);
        }
        d.enter(2);
        d.enter(2);
        d.enter(2);
        // now replica 1 (depth 1) is the shallowest
        assert_eq!(lq.route(&s, &d), 1);
        d.leave(0);
        d.leave(0);
        // replica 0 drained to zero
        assert_eq!(lq.route(&s, &d), 0);
    }

    #[test]
    fn policies_skip_dead_replicas_and_recover_on_revival() {
        let d = QueueDepths::new(3);
        let s = sample(3);

        let rr = RoundRobin::new();
        d.set_alive(1, false);
        // cursor picks 0,1,2 — pick 1 walks forward to 2
        assert_eq!(rr.route(&s, &d), 0);
        assert_eq!(rr.route(&s, &d), 2);
        assert_eq!(rr.route(&s, &d), 2);
        d.set_alive(1, true);
        assert_eq!(rr.route(&s, &d), 0);
        assert_eq!(rr.route(&s, &d), 1);

        let lq = LeastQueued::new();
        d.set_alive(2, false);
        d.enter(0);
        d.enter(0);
        // replica 2 is empty but dead: the shallow-queue scan must pick 1
        for _ in 0..3 {
            assert_eq!(lq.route(&s, &d), 1);
        }
        d.enter(1);
        d.set_alive(2, true);
        // revived replica 2 (depth 0) is now the shallowest live queue
        assert_eq!(lq.route(&s, &d), 2);
        assert_eq!(d.live_count(), 3);
    }

    #[test]
    fn all_dead_routes_fall_back_to_original_pick() {
        let d = QueueDepths::new(2);
        d.set_alive(0, false);
        d.set_alive(1, false);
        assert_eq!(d.live_count(), 0);
        let rr = RoundRobin::new();
        let s = sample(4);
        // nothing alive: the pick degrades to the raw cursor value so the
        // request queues for the supervisor's respawn instead of panicking
        assert_eq!(rr.route(&s, &d), 0);
        assert_eq!(rr.route(&s, &d), 1);
        let lq = LeastQueued::new();
        let p0 = lq.route(&s, &d);
        assert!(p0 < 2);
    }

    #[test]
    fn heartbeats_count_pickups() {
        let d = QueueDepths::new(2);
        assert_eq!(d.beats(0), 0);
        d.beat(0);
        d.beat(0);
        d.beat(1);
        assert_eq!(d.beats(0), 2);
        assert_eq!(d.beats(1), 1);
    }

    #[test]
    fn queue_depths_track_enter_leave() {
        let d = QueueDepths::new(2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.depth(0), 0);
        d.enter(0);
        d.enter(0);
        d.enter(1);
        assert_eq!(d.depth(0), 2);
        assert_eq!(d.depth(1), 1);
        d.leave(0);
        assert_eq!(d.depth(0), 1);
    }
}
