//! Pluggable request routing for the serving stack: a [`RoutePolicy`]
//! decides which detector replica serves each request.
//!
//! Three built-in policies:
//!
//! * [`RoundRobin`] — the pre-redesign behavior: an atomic cursor cycling
//!   over replicas, blind to queue state and index locality.
//! * [`LeastQueued`] — per-replica queue-depth gauges ([`QueueDepths`]:
//!   incremented at dispatch, decremented when the replica finishes a
//!   request); each request goes to the shallowest queue, with a rotating
//!   scan start so ties don't pile onto replica 0.
//! * [`PlanAffinity`] — plan-driven shard routing (the ROADMAP item): a
//!   request's compressed sparse indices are pushed through the planner's
//!   bijections and TT prefix map ([`AffinityMap`]) — the exact quantity
//!   `TtPlan` groups rows by — and the mixed key picks the replica.
//!   Requests sharing hot prefixes keep landing on the same replica, so
//!   that replica's plan scratch, reuse-buffer partial products and
//!   tiled row sets (`TtPlan::tile_slots`) stay warm.
//!
//! Replicas are clones of one trained detector, so the policy can NEVER
//! change a verdict — only queueing and cache behavior.  Pinned by
//! `tests/serve_equivalence.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::access::AffinityMap;
use crate::powersys::dataset::Sample;

/// Per-replica in-flight request gauges, shared between the server's
/// dispatch side (enter) and the replica workers (leave).
pub struct QueueDepths {
    depths: Vec<AtomicUsize>,
}

impl QueueDepths {
    pub fn new(replicas: usize) -> QueueDepths {
        QueueDepths {
            depths: (0..replicas).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.depths.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.depths.is_empty()
    }

    /// Current in-flight request count of replica `i`.
    #[inline]
    pub fn depth(&self, i: usize) -> usize {
        self.depths[i].load(Ordering::Relaxed)
    }

    /// A request was dispatched to replica `i`.
    #[inline]
    pub fn enter(&self, i: usize) {
        self.depths[i].fetch_add(1, Ordering::Relaxed);
    }

    /// Replica `i` finished a request.
    #[inline]
    pub fn leave(&self, i: usize) {
        self.depths[i].fetch_sub(1, Ordering::Relaxed);
    }
}

/// A routing decision per request.  Implementations must be `Sync`:
/// `route` is called concurrently from every closed-loop client thread.
pub trait RoutePolicy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Pick the replica (`< depths.len()`) that serves `sample`.
    fn route(&self, sample: &Sample, depths: &QueueDepths) -> usize;
}

/// Blind cyclic dispatch (the legacy `StreamingServer` behavior).
#[derive(Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&self, _sample: &Sample, depths: &QueueDepths) -> usize {
        self.next.fetch_add(1, Ordering::Relaxed) % depths.len()
    }
}

/// Route to the replica with the fewest in-flight requests.  The scan
/// start rotates so equal-depth replicas share the load instead of the
/// lowest index absorbing every tie.
#[derive(Default)]
pub struct LeastQueued {
    cursor: AtomicUsize,
}

impl LeastQueued {
    pub fn new() -> LeastQueued {
        LeastQueued::default()
    }
}

impl RoutePolicy for LeastQueued {
    fn name(&self) -> &'static str {
        "least_queued"
    }

    fn route(&self, _sample: &Sample, depths: &QueueDepths) -> usize {
        let n = depths.len();
        let start = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_depth = depths.depth(start);
        for k in 1..n {
            let i = (start + k) % n;
            let d = depths.depth(i);
            if d < best_depth {
                best = i;
                best_depth = d;
            }
        }
        best
    }
}

/// Plan-driven shard routing: hash the request's post-bijection TT
/// prefixes ([`AffinityMap::key`]) onto a replica.  Stateless and
/// deterministic — the same hot rows always land on the same replica,
/// whose plan scratch and embedding tiles are already warm.
pub struct PlanAffinity {
    map: AffinityMap,
}

impl PlanAffinity {
    pub fn new(map: AffinityMap) -> PlanAffinity {
        PlanAffinity { map }
    }
}

impl RoutePolicy for PlanAffinity {
    fn name(&self) -> &'static str {
        "plan_affinity"
    }

    fn route(&self, sample: &Sample, depths: &QueueDepths) -> usize {
        (self.map.key(&sample.sparse) % depths.len() as u64) as usize
    }
}

/// Route-policy selector for config / CLI (`[serve] policy = "…"`,
/// `--policy …`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastQueued,
    PlanAffinity,
}

impl Policy {
    pub fn as_str(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "round_robin",
            Policy::LeastQueued => "least_queued",
            Policy::PlanAffinity => "plan_affinity",
        }
    }

    /// Parse a policy name; accepts `-` or `_` separators.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        match s.trim().to_ascii_lowercase().replace('-', "_").as_str() {
            "round_robin" | "rr" => Ok(Policy::RoundRobin),
            "least_queued" | "lq" => Ok(Policy::LeastQueued),
            "plan_affinity" | "pa" => Ok(Policy::PlanAffinity),
            other => anyhow::bail!(
                "unknown route policy '{other}' \
                 (expected round_robin | least_queued | plan_affinity)"
            ),
        }
    }
}

impl std::str::FromStr for Policy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Policy> {
        Policy::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::powersys::dataset::{N_DENSE, N_SPARSE};

    fn sample(seed: u64) -> Sample {
        let mut rng = crate::util::prng::Rng::new(seed);
        let mut sparse = [0u64; N_SPARSE];
        for v in sparse.iter_mut() {
            *v = rng.below(100);
        }
        Sample { dense: [0.0; N_DENSE], sparse, label: 0.0, attack_kind: None }
    }

    #[test]
    fn policy_parse_round_trips_and_rejects_garbage() {
        for p in [Policy::RoundRobin, Policy::LeastQueued, Policy::PlanAffinity] {
            assert_eq!(Policy::parse(p.as_str()).unwrap(), p);
        }
        assert_eq!(Policy::parse("plan-affinity").unwrap(), Policy::PlanAffinity);
        assert_eq!(Policy::parse("RR").unwrap(), Policy::RoundRobin);
        assert!(Policy::parse("random").is_err());
    }

    #[test]
    fn round_robin_cycles_replicas() {
        let d = QueueDepths::new(3);
        let rr = RoundRobin::new();
        let s = sample(1);
        let picks: Vec<usize> = (0..6).map(|_| rr.route(&s, &d)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_queued_prefers_shallow_queues() {
        let d = QueueDepths::new(3);
        d.enter(0);
        d.enter(0);
        d.enter(1);
        let lq = LeastQueued::new();
        let s = sample(2);
        // replica 2 is empty: every route must pick it until it fills
        for _ in 0..4 {
            assert_eq!(lq.route(&s, &d), 2);
        }
        d.enter(2);
        d.enter(2);
        d.enter(2);
        // now replica 1 (depth 1) is the shallowest
        assert_eq!(lq.route(&s, &d), 1);
        d.leave(0);
        d.leave(0);
        // replica 0 drained to zero
        assert_eq!(lq.route(&s, &d), 0);
    }

    #[test]
    fn queue_depths_track_enter_leave() {
        let d = QueueDepths::new(2);
        assert_eq!(d.len(), 2);
        assert_eq!(d.depth(0), 0);
        d.enter(0);
        d.enter(0);
        d.enter(1);
        assert_eq!(d.depth(0), 2);
        assert_eq!(d.depth(1), 1);
        d.leave(0);
        assert_eq!(d.depth(0), 1);
    }
}
