//! `ServeSession` — the one-stop serving builder.  Replaces the
//! `Detector::new` / `Detector::with_planner` / `StreamingServer::start`
//! / `start_sharded` constructor maze with a single fluent API:
//!
//! ```ignore
//! let server = ServeSession::from_trained(engine, planner)
//!     .replicas(4)
//!     .policy(Policy::PlanAffinity)
//!     .max_batch(8)
//!     .deadline(Duration::from_millis(2))
//!     .start();
//! ```
//!
//! The builder threads everything that must stay consistent end to end:
//! the FROZEN planner the model trained under (bijections + layout
//! policy), per-replica intra-step worker pinning (replica-level
//! sharding, so N replicas don't fan out to N×workers threads), the
//! route policy (with `PlanAffinity` snapshotting the planner's
//! [`AffinityMap`](crate::access::AffinityMap) before it is moved into
//! the detector), and the micro-batch cap + fill deadline.

use std::sync::Arc;
use std::time::Duration;

use crate::access::AccessPlanner;
use crate::coordinator::engine::NativeDlrm;
use crate::runtime::autotune::{AutotuneCfg, ServeTuneCfg};
use crate::runtime::fault::FaultPlan;
use crate::tt::table::QuantizeMode;
use crate::serve::detector::Detector;
use crate::serve::router::{LeastQueued, PlanAffinity, Policy, RoundRobin, RoutePolicy};
use crate::serve::server::{GuardCfg, StreamingServer};

/// `[serve]` section of the run config (+ the matching CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct ServeCfg {
    /// Detector replicas (`[serve] replicas` / `--replicas`; the old
    /// overloaded `--workers` now only sets training workers).
    pub replicas: usize,
    /// Micro-batch cap per replica (`[serve] max_batch` / `--max-batch`).
    pub max_batch: usize,
    /// How long a replica waits for a micro-batch to fill, in µs
    /// (`[serve] deadline_us` / `--deadline-us`); 0 = drain-only.
    pub deadline_us: u64,
    /// Route policy (`[serve] policy` / `--policy`).
    pub policy: Policy,
    /// Per-call dispatch charge in µs (`[serve] dispatch_us` /
    /// `--dispatch-us`): the platform's launch overhead.
    pub dispatch_us: u64,
    /// Closed-loop client count (`[serve] clients` / `--clients`);
    /// 0 means 2× replicas.
    pub clients: usize,
    /// Open-loop Poisson arrival rate in requests/s (`[serve]
    /// arrival_rate` / `--arrival-rate`); 0 selects the closed loop.
    pub arrival_rate: f64,
    /// Load-shedding budget in µs (`[serve] shed_budget_us` /
    /// `--shed-budget-us`): requests whose queue-delay estimate exceeds
    /// it are refused with `Reply { shed: true }`.  0 = never shed.
    pub shed_budget_us: u64,
    /// Supervisor heartbeat period in ms (`[serve] heartbeat_ms` /
    /// `--heartbeat-ms`): dead/hung replicas are respawned from the
    /// frozen snapshot.  0 = no supervision.
    pub heartbeat_ms: u64,
    /// Hung-replica threshold in ms (`[serve] hang_ms` / `--hang-ms`):
    /// a non-empty queue with a frozen heartbeat for this long triggers
    /// a respawn-over.
    pub hang_ms: u64,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            replicas: 1,
            max_batch: 1,
            deadline_us: 0,
            policy: Policy::RoundRobin,
            dispatch_us: 100,
            clients: 0,
            arrival_rate: 0.0,
            shed_budget_us: 0,
            heartbeat_ms: 0,
            hang_ms: 200,
        }
    }
}

impl ServeCfg {
    /// Closed-loop concurrency: explicit `clients`, or 2× replicas so
    /// every replica can stay busy while another request is in flight.
    pub fn effective_clients(&self) -> usize {
        if self.clients == 0 {
            self.replicas * 2
        } else {
            self.clients
        }
    }
}

/// Fluent serving builder; see the module docs for the full example.
#[derive(Clone)]
pub struct ServeSession {
    engine: NativeDlrm,
    planner: AccessPlanner,
    threshold: f32,
    replicas: usize,
    max_batch: usize,
    deadline: Duration,
    dispatch: Duration,
    policy: Policy,
    quantize: QuantizeMode,
    autotune: Option<ServeTuneCfg>,
    guard: GuardCfg,
    fault: Option<Arc<FaultPlan>>,
}

impl ServeSession {
    /// Serve a trained engine through the SPECIFIC planner it trained
    /// under — required whenever reordering was active: the learned
    /// embedding rows are only consistent with that planner's bijections.
    /// The planner is frozen by the detector (read-only traffic never
    /// advances online-reorder state).
    pub fn from_trained(engine: NativeDlrm, planner: AccessPlanner) -> ServeSession {
        ServeSession {
            engine,
            planner,
            threshold: 0.5,
            replicas: 1,
            max_batch: 1,
            deadline: Duration::ZERO,
            dispatch: Duration::ZERO,
            policy: Policy::RoundRobin,
            quantize: QuantizeMode::Off,
            autotune: None,
            guard: GuardCfg::default(),
            fault: None,
        }
    }

    /// Serve an engine trained without reordering (identity planner).
    pub fn from_engine(engine: NativeDlrm) -> ServeSession {
        let planner = AccessPlanner::for_engine_cfg(&engine.cfg);
        ServeSession::from_trained(engine, planner)
    }

    /// Verdict threshold on the attack probability (default 0.5).
    pub fn threshold(mut self, t: f32) -> ServeSession {
        self.threshold = t;
        self
    }

    /// Detector replica count (default 1).
    pub fn replicas(mut self, n: usize) -> ServeSession {
        self.replicas = n.max(1);
        self
    }

    /// Route policy (default round-robin).
    pub fn policy(mut self, p: Policy) -> ServeSession {
        self.policy = p;
        self
    }

    /// Micro-batch cap per replica (default 1 = no batching).
    pub fn max_batch(mut self, b: usize) -> ServeSession {
        self.max_batch = b.max(1);
        self
    }

    /// How long a replica waits for a micro-batch to fill before scoring
    /// what it has (default zero = drain-only batching).
    pub fn deadline(mut self, d: Duration) -> ServeSession {
        self.deadline = d;
        self
    }

    /// Per-call dispatch charge (platform launch overhead; default zero).
    pub fn dispatch(mut self, d: Duration) -> ServeSession {
        self.dispatch = d;
        self
    }

    /// Quantized serving mode (`[tt] quantize` / `--quantize`; default
    /// off).  On [`ServeSession::start`] every TT table is frozen into
    /// int8 or f16 core tiles and scored through the dequantize-in-
    /// microkernel fast path — a serving-only representation; the engine
    /// inside the server can no longer train.
    pub fn quantize(mut self, mode: QuantizeMode) -> ServeSession {
        self.quantize = mode;
        self
    }

    /// Attach the serve-batching autotune loop (`[autotune]` /
    /// `--autotune`): each replica adapts its `max_batch`/`deadline`
    /// from the queue-delay vs service-time split, bounded by the p99
    /// target.  A config with the serve loop disabled installs nothing —
    /// the server runs the exact static path.
    pub fn autotune(mut self, cfg: &AutotuneCfg) -> ServeSession {
        self.autotune = cfg.serve_on().then(|| cfg.serve_tune());
        self
    }

    /// Load-shedding budget: refuse requests whose queue-delay estimate
    /// exceeds it (default zero = never shed).
    pub fn shed_budget(mut self, d: Duration) -> ServeSession {
        self.guard.shed_budget = d;
        self
    }

    /// Supervisor heartbeat period (default zero = no supervisor
    /// thread, no respawns).
    pub fn heartbeat(mut self, d: Duration) -> ServeSession {
        self.guard.heartbeat = d;
        self
    }

    /// Hung-replica threshold for the supervisor (default 200 ms).
    pub fn hang(mut self, d: Duration) -> ServeSession {
        self.guard.hang = d;
        self
    }

    /// Attach a chaos plan (`[fault]` / `--fault-*`); `None` (the
    /// default) leaves every fault branch unentered.
    pub fn fault(mut self, plan: Option<Arc<FaultPlan>>) -> ServeSession {
        self.fault = plan;
        self
    }

    /// Apply a `[serve]` config section (replicas, batching + deadline,
    /// policy, dispatch, shedding + supervision).  Loop shape
    /// (`clients` / `arrival_rate`) stays with the driver — see
    /// [`ServeCfg::effective_clients`] and `serve::load`.
    pub fn with_cfg(self, cfg: &ServeCfg) -> ServeSession {
        self.replicas(cfg.replicas)
            .max_batch(cfg.max_batch)
            .deadline(Duration::from_micros(cfg.deadline_us))
            .policy(cfg.policy)
            .dispatch(Duration::from_micros(cfg.dispatch_us))
            .shed_budget(Duration::from_micros(cfg.shed_budget_us))
            .heartbeat(Duration::from_millis(cfg.heartbeat_ms))
            .hang(Duration::from_millis(cfg.hang_ms))
    }

    /// Spawn the replica workers and return the running server.
    pub fn start(mut self) -> StreamingServer {
        let n = self.replicas;
        // Freeze before cloning replicas so all of them share the same
        // quantized tiles (quantize once, not once per replica).
        if self.quantize != QuantizeMode::Off {
            self.engine.freeze_quantized(self.quantize);
        }
        // Replica-level sharding: pin each replica's intra-step pool to 1
        // so N replicas don't fan out to N×workers threads.
        self.engine.set_workers(1);
        // Snapshot the affinity view BEFORE the planner moves into the
        // detector: PlanAffinity must hash through the same bijections
        // the replicas plan with.
        let affinity = self.planner.affinity_map();
        let det = Detector::with_planner(self.engine, self.threshold, self.planner);
        let mut replicas = Vec::with_capacity(n);
        for _ in 1..n {
            replicas.push(det.clone());
        }
        replicas.push(det);
        let policy: Arc<dyn RoutePolicy> = match self.policy {
            Policy::RoundRobin => Arc::new(RoundRobin::new()),
            Policy::LeastQueued => Arc::new(LeastQueued::new()),
            Policy::PlanAffinity => Arc::new(PlanAffinity::new(affinity)),
        };
        StreamingServer::spawn_supervised(
            replicas,
            self.max_batch,
            self.deadline,
            self.dispatch,
            policy,
            self.autotune,
            self.guard,
            self.fault,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineCfg;
    use crate::powersys::dataset::{generate, DatasetCfg, SparseVocab};
    use crate::util::prng::Rng;

    #[test]
    fn serve_cfg_defaults_and_effective_clients() {
        let d = ServeCfg::default();
        assert_eq!(d.replicas, 1);
        assert_eq!(d.max_batch, 1);
        assert_eq!(d.deadline_us, 0);
        assert_eq!(d.policy, Policy::RoundRobin);
        assert_eq!(d.arrival_rate, 0.0);
        let c = ServeCfg { replicas: 3, ..Default::default() };
        assert_eq!(c.effective_clients(), 6);
        let c = ServeCfg { replicas: 3, clients: 2, ..Default::default() };
        assert_eq!(c.effective_clients(), 2);
    }

    #[test]
    fn builder_starts_configured_server() {
        let ds = generate(&DatasetCfg {
            n_normal: 24,
            n_attack: 6,
            vocab: SparseVocab::ieee118(1.0 / 2000.0),
            n_profiles: 10,
            noise_std: 0.005,
            seed: 5,
        });
        let engine = NativeDlrm::new(EngineCfg::ieee118(1.0 / 2000.0), &mut Rng::new(6));
        let server = ServeSession::from_engine(engine)
            .replicas(3)
            .policy(Policy::LeastQueued)
            .max_batch(4)
            .threshold(0.4)
            .start();
        assert_eq!(server.replicas(), 3);
        assert_eq!(server.policy_name(), "least_queued");
        let report = server.run_stream(&ds.samples[..10], 0);
        assert_eq!(report.served, 10);
        assert_eq!(report.lifetime_served, 10);
        assert_eq!(report.policy, "least_queued");
    }
}
