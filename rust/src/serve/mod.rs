//! Streaming FDIA detection service (paper §V-M, Table VI): batch-1
//! real-time inference with latency/TPS accounting, plus an optional
//! micro-batching router.

pub mod detector;
pub mod server;

pub use detector::{Detector, Verdict};
pub use server::{ServeReport, StreamingServer};
