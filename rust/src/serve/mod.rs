//! Streaming FDIA detection service (paper §V-M, Table VI), redesigned
//! as a composable serving stack:
//!
//! * [`Detector`] (`detector`) — the detection head: trained engine +
//!   frozen planner + per-replica plan scratch.
//! * [`RoutePolicy`] (`router`) — pluggable request routing:
//!   [`RoundRobin`], [`LeastQueued`] (per-replica depth gauges), and
//!   [`PlanAffinity`] (plan-driven shard routing: requests hash through
//!   the planner's bijection + TT-prefix map so hot rows keep landing on
//!   the replica whose plan scratch and tiles are warm).
//! * [`StreamingServer`] (`server`) — N replica workers, micro-batching
//!   with an optional fill deadline, queue-delay/service-time split per
//!   [`Reply`], stream-only vs lifetime accounting in [`ServeReport`].
//! * [`ServeSession`] (`session`) — the fluent builder that wires all of
//!   the above (`ServeSession::from_trained(engine, planner)
//!   .replicas(n).policy(p).max_batch(b).deadline(d).start()`).
//! * [`run_open_loop`] (`load`) — Poisson open-loop load generation:
//!   attack-window percentiles under load, split into queueing and
//!   service.
//!
//! Invariant: replicas are clones of one trained detector, so route
//! policy, replica count, and micro-batching can never change a verdict
//! — pinned bitwise by `tests/serve_equivalence.rs`.
//!
//! Fault tolerance (PR 8): replica queues survive worker death, a
//! supervisor ([`GuardCfg::heartbeat`]) respawns dead/hung replicas from
//! a frozen snapshot, the router sheds under overload
//! ([`GuardCfg::shed_budget`], `Reply { shed: true }`), and all of it is
//! driven deterministically by the
//! [`FaultPlan`](crate::runtime::fault::FaultPlan) chaos harness —
//! disabled, the stack is bit-identical to the unguarded one (pinned by
//! `tests/fault_equivalence.rs`).

pub mod detector;
pub mod load;
pub mod router;
pub mod server;
pub mod session;

pub use detector::{Detector, Verdict};
pub use load::{run_open_loop, run_open_loop_clocked, OpenLoopCfg, OpenLoopReport};
pub use router::{LeastQueued, PlanAffinity, Policy, QueueDepths, RoundRobin, RoutePolicy};
pub use server::{GuardCfg, Reply, ServeReport, StreamingServer};
pub use session::{ServeCfg, ServeSession};
