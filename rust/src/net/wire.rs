//! Binary wire format for the multi-node serving tier.
//!
//! Every frame is a 1-byte tag followed by little-endian fixed-width
//! fields; variable-length sequences (dense/sparse vectors, the affinity
//! snapshot string) carry a `u32` element count first.  The framing
//! layer (`net/rpc.rs`) prefixes the encoded payload with a `u32`
//! length, so the codec here never needs to guess where a frame ends.
//!
//! | tag | frame          | payload                                            |
//! |-----|----------------|----------------------------------------------------|
//! | 1   | `Infer`        | seq u64, dense `[f32]`, sparse `[u64]`, label f32  |
//! | 2   | `Reply`        | seq, prob f32, latency/queue ns u64, shed u8, gauge|
//! | 3   | `Heartbeat`    | seq u64                                            |
//! | 4   | `HeartbeatAck` | seq u64, gauge                                     |
//! | 5   | `Join`         | node u64, affinity snapshot JSON string            |
//! | 6   | `JoinAck`      | node u64, ok u8                                    |
//! | 7   | `Leave`        | node u64                                           |
//! | 8   | `Shutdown`     | —                                                  |
//!
//! A `NodeGauge` (queue depth, live replicas, served/shed/respawn
//! counters) piggybacks on every `Reply` and `HeartbeatAck`, giving the
//! client-side router a remote view of `QueueDepths` without a separate
//! metrics channel.

use anyhow::{bail, ensure, Result};

use crate::powersys::dataset::{Sample, N_DENSE, N_SPARSE};

pub const TAG_INFER: u8 = 1;
pub const TAG_REPLY: u8 = 2;
pub const TAG_HEARTBEAT: u8 = 3;
pub const TAG_HEARTBEAT_ACK: u8 = 4;
pub const TAG_JOIN: u8 = 5;
pub const TAG_JOIN_ACK: u8 = 6;
pub const TAG_LEAVE: u8 = 7;
pub const TAG_SHUTDOWN: u8 = 8;

/// Remote load/liveness gauge piggybacked on replies and heartbeat acks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeGauge {
    /// Total queued requests across the node's replicas.
    pub depth: u32,
    /// Replicas currently alive under the node's supervisor.
    pub live: u32,
    /// Infer requests accepted by the node so far.
    pub served: u64,
    /// Requests shed by the node's admission guard.
    pub shed: u64,
    /// Replica respawns performed by the node's supervisor.
    pub respawns: u64,
}

/// One RPC frame.  See the module table for the wire layout.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Infer { seq: u64, dense: Vec<f32>, sparse: Vec<u64>, label: f32 },
    Reply {
        seq: u64,
        prob: f32,
        latency_ns: u64,
        queue_delay_ns: u64,
        shed: bool,
        gauge: NodeGauge,
    },
    Heartbeat { seq: u64 },
    HeartbeatAck { seq: u64, gauge: NodeGauge },
    Join { node: u64, affinity: String },
    JoinAck { node: u64, ok: bool },
    Leave { node: u64 },
    Shutdown,
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_gauge(buf: &mut Vec<u8>, g: &NodeGauge) {
    put_u32(buf, g.depth);
    put_u32(buf, g.live);
    put_u64(buf, g.served);
    put_u64(buf, g.shed);
    put_u64(buf, g.respawns);
}

/// Bounds-checked little-endian reader over a decoded payload.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.at + n <= self.buf.len(),
            "frame truncated: need {n} bytes at offset {} of {}",
            self.at,
            self.buf.len()
        );
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    /// `take` with a compile-time width: the length check lives in
    /// `take`, so the array conversion cannot fail and the decode path
    /// stays panic-free on truncated or hostile frames.
    fn take_n<const N: usize>(&mut self) -> Result<[u8; N]> {
        let s = self.take(N)?;
        let mut a = [0u8; N];
        a.copy_from_slice(s);
        Ok(a)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take_n()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take_n()?))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take_n()?))
    }

    fn gauge(&mut self) -> Result<NodeGauge> {
        Ok(NodeGauge {
            depth: self.u32()?,
            live: self.u32()?,
            served: self.u64()?,
            shed: self.u64()?,
            respawns: self.u64()?,
        })
    }

    fn count(&mut self) -> Result<usize> {
        let n = self.u32()? as usize;
        // Each element is at least one byte; a count beyond the buffer
        // is corrupt and would otherwise trigger a huge allocation.
        ensure!(n <= self.buf.len(), "corrupt element count {n}");
        Ok(n)
    }

    fn done(&self) -> Result<()> {
        ensure!(self.at == self.buf.len(), "{} trailing bytes after frame", self.buf.len() - self.at);
        Ok(())
    }
}

impl Frame {
    /// Build an `Infer` frame from a detector sample.
    pub fn from_sample(seq: u64, s: &Sample) -> Frame {
        Frame::Infer {
            seq,
            dense: s.dense.to_vec(),
            sparse: s.sparse.to_vec(),
            label: s.label,
        }
    }

    /// Reconstruct the sample carried by an `Infer` frame.  The attack
    /// kind is generator-side metadata and does not cross the wire.
    pub fn sample(&self) -> Result<Sample> {
        let Frame::Infer { dense, sparse, label, .. } = self else {
            bail!("sample() on a non-Infer frame");
        };
        ensure!(dense.len() == N_DENSE, "dense arity {} != {N_DENSE}", dense.len());
        ensure!(sparse.len() == N_SPARSE, "sparse arity {} != {N_SPARSE}", sparse.len());
        let mut d = [0f32; N_DENSE];
        d.copy_from_slice(dense);
        let mut sp = [0u64; N_SPARSE];
        sp.copy_from_slice(sparse);
        Ok(Sample { dense: d, sparse: sp, label: *label, attack_kind: None })
    }

    /// Append the binary encoding of this frame to `buf`.
    pub fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Frame::Infer { seq, dense, sparse, label } => {
                buf.push(TAG_INFER);
                put_u64(buf, *seq);
                put_u32(buf, dense.len() as u32);
                for v in dense {
                    put_f32(buf, *v);
                }
                put_u32(buf, sparse.len() as u32);
                for v in sparse {
                    put_u64(buf, *v);
                }
                put_f32(buf, *label);
            }
            Frame::Reply { seq, prob, latency_ns, queue_delay_ns, shed, gauge } => {
                buf.push(TAG_REPLY);
                put_u64(buf, *seq);
                put_f32(buf, *prob);
                put_u64(buf, *latency_ns);
                put_u64(buf, *queue_delay_ns);
                buf.push(*shed as u8);
                put_gauge(buf, gauge);
            }
            Frame::Heartbeat { seq } => {
                buf.push(TAG_HEARTBEAT);
                put_u64(buf, *seq);
            }
            Frame::HeartbeatAck { seq, gauge } => {
                buf.push(TAG_HEARTBEAT_ACK);
                put_u64(buf, *seq);
                put_gauge(buf, gauge);
            }
            Frame::Join { node, affinity } => {
                buf.push(TAG_JOIN);
                put_u64(buf, *node);
                put_u32(buf, affinity.len() as u32);
                buf.extend_from_slice(affinity.as_bytes());
            }
            Frame::JoinAck { node, ok } => {
                buf.push(TAG_JOIN_ACK);
                put_u64(buf, *node);
                buf.push(*ok as u8);
            }
            Frame::Leave { node } => {
                buf.push(TAG_LEAVE);
                put_u64(buf, *node);
            }
            Frame::Shutdown => buf.push(TAG_SHUTDOWN),
        }
    }

    /// Decode one frame from an exact payload slice (no length prefix).
    pub fn decode(buf: &[u8]) -> Result<Frame> {
        let mut c = Cursor { buf, at: 0 };
        let tag = c.u8()?;
        let f = match tag {
            TAG_INFER => {
                let seq = c.u64()?;
                let nd = c.count()?;
                let mut dense = Vec::with_capacity(nd);
                for _ in 0..nd {
                    dense.push(c.f32()?);
                }
                let ns = c.count()?;
                let mut sparse = Vec::with_capacity(ns);
                for _ in 0..ns {
                    sparse.push(c.u64()?);
                }
                let label = c.f32()?;
                Frame::Infer { seq, dense, sparse, label }
            }
            TAG_REPLY => Frame::Reply {
                seq: c.u64()?,
                prob: c.f32()?,
                latency_ns: c.u64()?,
                queue_delay_ns: c.u64()?,
                shed: c.u8()? != 0,
                gauge: c.gauge()?,
            },
            TAG_HEARTBEAT => Frame::Heartbeat { seq: c.u64()? },
            TAG_HEARTBEAT_ACK => Frame::HeartbeatAck { seq: c.u64()?, gauge: c.gauge()? },
            TAG_JOIN => {
                let node = c.u64()?;
                let n = c.count()?;
                let affinity = String::from_utf8(c.take(n)?.to_vec())?;
                Frame::Join { node, affinity }
            }
            TAG_JOIN_ACK => Frame::JoinAck { node: c.u64()?, ok: c.u8()? != 0 },
            TAG_LEAVE => Frame::Leave { node: c.u64()? },
            TAG_SHUTDOWN => Frame::Shutdown,
            t => bail!("unknown frame tag {t}"),
        };
        c.done()?;
        Ok(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        f.encode(&mut buf);
        let back = Frame::decode(&buf).expect("decode");
        assert_eq!(f, back);
    }

    #[test]
    fn every_variant_roundtrips() {
        let gauge = NodeGauge { depth: 3, live: 2, served: 77, shed: 1, respawns: 4 };
        roundtrip(Frame::Infer {
            seq: 42,
            dense: vec![0.5, -1.25, f32::MIN_POSITIVE, 0.0, 3.0, 9.5],
            sparse: vec![0, 1, u64::MAX, 7, 8, 9, 10],
            label: 1.0,
        });
        roundtrip(Frame::Reply {
            seq: 42,
            prob: 0.875,
            latency_ns: 1_234_567,
            queue_delay_ns: 89,
            shed: true,
            gauge,
        });
        roundtrip(Frame::Heartbeat { seq: 9 });
        roundtrip(Frame::HeartbeatAck { seq: 9, gauge });
        roundtrip(Frame::Join { node: 2, affinity: "{\"slots\":[]}".into() });
        roundtrip(Frame::JoinAck { node: 2, ok: true });
        roundtrip(Frame::Leave { node: 5 });
        roundtrip(Frame::Shutdown);
    }

    #[test]
    fn infer_frame_rebuilds_the_sample() {
        let s = Sample {
            dense: [0.1, 0.2, 0.3, 0.4, 0.5, 0.6],
            sparse: [1, 2, 3, 4, 5, 6, 7],
            label: 1.0,
            attack_kind: None,
        };
        let f = Frame::from_sample(11, &s);
        let back = f.sample().expect("sample");
        assert_eq!(s.dense, back.dense);
        assert_eq!(s.sparse, back.sparse);
        assert_eq!(s.label.to_bits(), back.label.to_bits());
    }

    #[test]
    fn truncated_and_garbage_frames_are_rejected() {
        let mut buf = Vec::new();
        Frame::Heartbeat { seq: 1 }.encode(&mut buf);
        assert!(Frame::decode(&buf[..buf.len() - 1]).is_err(), "truncated accepted");
        assert!(Frame::decode(&[0xFF, 0, 0]).is_err(), "unknown tag accepted");
        buf.push(0); // trailing byte
        assert!(Frame::decode(&buf).is_err(), "trailing bytes accepted");
        // corrupt element count must not allocate terabytes
        let mut inf = Vec::new();
        Frame::Infer { seq: 1, dense: vec![], sparse: vec![], label: 0.0 }.encode(&mut inf);
        inf[9] = 0xFF;
        inf[10] = 0xFF;
        inf[11] = 0xFF;
        inf[12] = 0xFF;
        assert!(Frame::decode(&inf).is_err(), "corrupt count accepted");
    }
}
