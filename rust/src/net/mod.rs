//! `net` — the multi-node serving tier (ISSUE 9).
//!
//! Promotes `serve::RoutePolicy` from intra-process thread routing to
//! cross-process node routing, with zero new dependencies:
//!
//! * [`wire`] — tagged little-endian frame codec (`Frame`, `NodeGauge`).
//! * [`rpc`] — `u32` length-prefixed framing over blocking
//!   `TcpStream`s, with a stop-aware read path for node handlers.
//! * [`ring`] — consistent-hash ring with virtual nodes, keyed by the
//!   `AccessPlanner::affinity_map()` FNV prefix key so hot TT prefix
//!   groups pin to nodes with warm quantized tiles; membership changes
//!   move a provably bounded ~1/n key fraction (property-tested).
//! * [`node`] — `recad node`: a TCP server wrapping a `ServeSession`
//!   (frozen snapshot, supervisor, shedding intact).
//! * [`router`] — `RemoteRouter` (the `RoutePolicy` surface over remote
//!   gauges), `NetClient` (liveness, eviction, requeue-on-death,
//!   rejoin, backpressure), and `run_open_loop_net`.
//!
//! Invariant: loopback multi-node serving is bit-identical to the
//! in-process `ServeSession` at equal model state — replicas are clones
//! of one trained detector whether they live behind a socket or not —
//! pinned by `tests/net_equivalence.rs`.

pub mod node;
pub mod ring;
pub mod router;
pub mod rpc;
pub mod wire;

pub use node::NodeServer;
pub use ring::HashRing;
pub use router::{run_open_loop_net, NetClient, NetLoopReport, RemoteReply, RemoteRouter};
pub use rpc::{read_frame, write_frame, MAX_FRAME};
pub use wire::{Frame, NodeGauge};
