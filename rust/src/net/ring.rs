//! Consistent-hash ring with virtual nodes.
//!
//! Keys are the FNV prefix keys produced by `AffinityMap::key`, so a hot
//! TT prefix group always lands on the node whose quantized tiles are
//! already warm for it.  Each physical node owns `vnodes` points on a
//! `u64` ring; a key routes to the owner of the first point at or after
//! `splitmix64(key)` (wrapping).  Because every point position is a pure
//! function of `(node, replica)`, membership changes have two properties
//! the tests pin:
//!
//! * **Bounded movement** — removing one of `n` nodes only reassigns
//!   keys that were owned by the removed node's points, an expected
//!   `1/n` fraction (property-tested at ≤ `2/n` with sampling slack);
//!   keys owned by surviving nodes never move.
//! * **Snap-back** — re-adding a node restores its exact points, so
//!   every key it used to own returns to it.
//!
//! `epoch` increments on every membership change; routing is a pure
//! function of `(key, epoch)`, which the router uses to reason about
//! in-flight requests across evictions.

use crate::util::prng::splitmix64;

#[derive(Clone, Debug)]
pub struct HashRing {
    /// Sorted `(position, node)` points; ties broken by node id.
    points: Vec<(u64, u64)>,
    /// Current member node ids, sorted.
    nodes: Vec<u64>,
    /// Virtual points per physical node.
    vnodes: usize,
    /// Bumped on every add/remove.
    epoch: u64,
}

/// Ring position of virtual replica `i` of `node` — a pure function, so
/// re-adding a node reclaims exactly the points it held before.
fn point_of(node: u64, i: usize) -> u64 {
    let mut s = node
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (i as u64).wrapping_mul(0xD1B5_4A32_D192_ED03);
    splitmix64(&mut s)
}

impl HashRing {
    pub fn new(vnodes: usize) -> HashRing {
        assert!(vnodes >= 1, "a node needs at least one ring point");
        HashRing { points: Vec::new(), nodes: Vec::new(), vnodes, epoch: 0 }
    }

    pub fn with_nodes(vnodes: usize, ids: &[u64]) -> HashRing {
        let mut r = HashRing::new(vnodes);
        for &id in ids {
            r.add(id);
        }
        r
    }

    /// Add a node; returns false (and changes nothing) if already present.
    pub fn add(&mut self, node: u64) -> bool {
        if self.contains(node) {
            return false;
        }
        self.nodes.push(node);
        self.nodes.sort_unstable();
        for i in 0..self.vnodes {
            self.points.push((point_of(node, i), node));
        }
        self.points.sort_unstable();
        self.epoch += 1;
        true
    }

    /// Remove a node; returns false if it was not a member.
    pub fn remove(&mut self, node: u64) -> bool {
        if !self.contains(node) {
            return false;
        }
        self.nodes.retain(|&n| n != node);
        self.points.retain(|&(_, n)| n != node);
        self.epoch += 1;
        true
    }

    pub fn contains(&self, node: u64) -> bool {
        self.nodes.binary_search(&node).is_ok()
    }

    pub fn nodes(&self) -> &[u64] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Owner of `key`, or None if the ring is empty.
    pub fn node_for(&self, key: u64) -> Option<u64> {
        if self.points.is_empty() {
            return None;
        }
        let mut s = key;
        let pos = splitmix64(&mut s);
        let i = self.points.partition_point(|p| p.0 < pos);
        let i = if i == self.points.len() { 0 } else { i };
        Some(self.points[i].1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn sample_keys(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(u64::MAX)).collect()
    }

    #[test]
    fn routing_is_deterministic_within_an_epoch() {
        let ring = HashRing::with_nodes(64, &[0, 1, 2, 3]);
        let clone = ring.clone();
        for k in sample_keys(1000, 5) {
            assert_eq!(ring.node_for(k), clone.node_for(k));
            assert_eq!(ring.node_for(k), ring.node_for(k));
        }
    }

    #[test]
    fn all_nodes_receive_some_share() {
        let ring = HashRing::with_nodes(64, &[0, 1, 2]);
        let mut counts = [0usize; 3];
        for k in sample_keys(3000, 9) {
            counts[ring.node_for(k).unwrap() as usize] += 1;
        }
        for (n, c) in counts.iter().enumerate() {
            assert!(*c > 0, "node {n} owns no keys");
        }
    }

    #[test]
    fn removing_one_of_n_moves_at_most_two_over_n() {
        let keys = sample_keys(10_000, 17);
        for n in [2usize, 3, 4, 8] {
            let ids: Vec<u64> = (0..n as u64).collect();
            let full = HashRing::with_nodes(64, &ids);
            let before: Vec<u64> = keys.iter().map(|&k| full.node_for(k).unwrap()).collect();
            let mut reduced = full.clone();
            reduced.remove(0);
            let mut moved = 0usize;
            for (i, &k) in keys.iter().enumerate() {
                let after = reduced.node_for(k).unwrap();
                if before[i] == 0 {
                    assert_ne!(after, 0, "key still routed to the removed node");
                } else {
                    assert_eq!(before[i], after, "a surviving node's key moved");
                }
                if before[i] != after {
                    moved += 1;
                }
            }
            let bound = 2.0 / n as f64;
            let frac = moved as f64 / keys.len() as f64;
            assert!(
                frac <= bound,
                "removing 1 of {n} moved {frac:.4} of keys (bound {bound:.4})"
            );
        }
    }

    #[test]
    fn readding_a_node_snaps_keys_back() {
        let keys = sample_keys(4000, 23);
        let full = HashRing::with_nodes(64, &[0, 1, 2]);
        let before: Vec<u64> = keys.iter().map(|&k| full.node_for(k).unwrap()).collect();
        let mut ring = full.clone();
        let e0 = ring.epoch();
        ring.remove(1);
        assert_eq!(ring.epoch(), e0 + 1);
        ring.add(1);
        assert_eq!(ring.epoch(), e0 + 2);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(before[i], ring.node_for(k).unwrap(), "key failed to snap back");
        }
    }

    #[test]
    fn membership_edge_cases() {
        let mut ring = HashRing::new(8);
        assert!(ring.node_for(7).is_none());
        assert!(ring.add(4));
        assert!(!ring.add(4), "double add accepted");
        assert_eq!(ring.node_for(7), Some(4), "singleton ring must own every key");
        assert!(ring.remove(4));
        assert!(!ring.remove(4), "double remove accepted");
        assert!(ring.is_empty());
    }
}
