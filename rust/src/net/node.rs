//! `recad node`: a serving node exposing a `ServeSession` over TCP.
//!
//! A `NodeServer` binds a listener and wraps a started
//! `StreamingServer`, so everything the in-process tier provides —
//! frozen snapshot, supervisor respawn, EWMA shedding — is intact
//! behind the socket.  Per connection, two threads cooperate:
//!
//! * the **handler** reads frames with a short read timeout (polling the
//!   node stop flag between partial reads), turns `Infer` frames into
//!   `submit()` calls, and answers heartbeats/joins inline;
//! * the **reply pump** drains the per-request reply receivers in
//!   submission order and writes `Reply` frames back, piggybacking a
//!   `NodeGauge` snapshot on each one.
//!
//! The handler and the pump share the write half of the socket behind a
//! mutex, so heartbeat acks interleave safely with replies.
//!
//! **Chaos**: when a `FaultPlan` with a node-kill verdict is attached,
//! the handler checks `node_kill_now` *before* submitting each request;
//! when the verdict fires the node records the event and stops without
//! replying — the triggering request is genuinely lost in flight and the
//! client router must re-route it, which is exactly what the zero-drop
//! test pins.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::access::AffinityMap;
use crate::runtime::FaultPlan;
use crate::serve::{Reply, ServeSession, StreamingServer};
use crate::util::json::Json;
use crate::util::sync::lock_recover;

use super::rpc::{read_frame_interruptible, write_frame, ReadOutcome};
use super::wire::{Frame, NodeGauge};

/// Read-timeout granularity for connection handlers; bounds how stale a
/// stop-flag observation can be.
const POLL: Duration = Duration::from_millis(25);

pub struct NodeServer {
    id: u64,
    generation: u64,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    accept: Option<thread::JoinHandle<Arc<StreamingServer>>>,
}

fn gauge_of(server: &StreamingServer, served: &AtomicU64) -> NodeGauge {
    let depths = server.queue_depths();
    let mut depth = 0u32;
    for i in 0..depths.len() {
        depth += depths.depth(i) as u32;
    }
    NodeGauge {
        depth,
        live: depths.live_count() as u32,
        served: served.load(Ordering::Relaxed),
        shed: server.shed_count(),
        respawns: server.respawns(),
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_conn(
    stream: TcpStream,
    server: Arc<StreamingServer>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    fault: Option<Arc<FaultPlan>>,
    id: u64,
    generation: u64,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(POLL));
    let writer = Arc::new(Mutex::new(match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    }));
    let mut reader = stream;

    // Reply pump: preserves submission order per connection, so a
    // client reading sequentially never sees seq reordering from one
    // node (ordering across nodes is the router's concern).
    let (pending_tx, pending_rx) = mpsc::channel::<(u64, mpsc::Receiver<Reply>)>();
    let pump = {
        let writer = Arc::clone(&writer);
        let server = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let served = Arc::clone(&served);
        // lint:allow(D4) reply pump; joined by handle_conn before the connection closes
        thread::spawn(move || {
            for (seq, rx) in pending_rx {
                if stop.load(Ordering::Relaxed) {
                    break; // killed: in-flight replies are lost on purpose
                }
                let reply = match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => r,
                    Err(_) => continue, // replica severed the reply; client re-routes
                };
                let frame = Frame::Reply {
                    seq,
                    prob: reply.prob,
                    latency_ns: reply.latency.as_nanos() as u64,
                    queue_delay_ns: reply.queue_delay.as_nanos() as u64,
                    shed: reply.shed,
                    gauge: gauge_of(&server, &served),
                };
                let mut w = lock_recover(&writer);
                if write_frame(&mut *w, &frame).is_err() {
                    break;
                }
            }
        })
    };

    loop {
        let frame = match read_frame_interruptible(&mut reader, &stop) {
            Ok(ReadOutcome::Frame(f)) => f,
            Ok(ReadOutcome::Eof) | Ok(ReadOutcome::Stopped) | Err(_) => break,
        };
        match frame {
            Frame::Infer { seq, .. } => {
                let sample = match frame.sample() {
                    Ok(s) => s,
                    Err(_) => break, // malformed request: drop the connection
                };
                let n = served.load(Ordering::Relaxed) + 1;
                if let Some(plan) = &fault {
                    if plan.node_kill_now(id, generation, n) {
                        plan.record("node_kill", id as usize, n);
                        stop.store(true, Ordering::Relaxed);
                        break; // the triggering request dies in flight
                    }
                }
                served.store(n, Ordering::Relaxed);
                let rx = server.submit(&sample);
                if pending_tx.send((seq, rx)).is_err() {
                    break;
                }
            }
            Frame::Heartbeat { seq } => {
                let ack = Frame::HeartbeatAck { seq, gauge: gauge_of(&server, &served) };
                let mut w = lock_recover(&writer);
                if write_frame(&mut *w, &ack).is_err() {
                    break;
                }
            }
            Frame::Join { node, affinity } => {
                // The router ships its affinity snapshot on join; a node
                // that cannot parse it must refuse so the client falls
                // back rather than routing against a different key space.
                let ok = Json::parse(&affinity)
                    .ok()
                    .map(|j| AffinityMap::from_json(&j).is_ok())
                    .unwrap_or(false);
                let ack = Frame::JoinAck { node, ok };
                let mut w = lock_recover(&writer);
                if write_frame(&mut *w, &ack).is_err() {
                    break;
                }
            }
            Frame::Leave { .. } => break,
            Frame::Shutdown => {
                stop.store(true, Ordering::Relaxed);
                break;
            }
            // client-bound frames arriving at a node are protocol errors
            Frame::Reply { .. } | Frame::HeartbeatAck { .. } | Frame::JoinAck { .. } => break,
        }
    }

    drop(pending_tx);
    let _ = pump.join();
    // Close both halves so the client's reader observes EOF promptly.
    if let Ok(w) = writer.lock() {
        let _ = w.shutdown(std::net::Shutdown::Both);
    }
}

impl NodeServer {
    /// Start a node: bind `listen` (use port 0 for tests), start the
    /// session, and serve connections until shutdown or node-kill.
    /// `generation` feeds the node-kill verdict: a respawned node passes
    /// 1 and is spared, mirroring the replica-kill discipline.
    pub fn spawn(
        id: u64,
        generation: u64,
        session: ServeSession,
        listen: &str,
        fault: Option<Arc<FaultPlan>>,
    ) -> Result<NodeServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("node {id}: bind {listen}"))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let server = Arc::new(session.start());
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let accept = {
            let server = Arc::clone(&server);
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            // lint:allow(D4) accept loop; joined on Shutdown via the stop flag below
            thread::spawn(move || {
                let mut conns = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let server = Arc::clone(&server);
                            let stop = Arc::clone(&stop);
                            let served = Arc::clone(&served);
                            let fault = fault.clone();
                            // lint:allow(D4) per-connection worker, joined from conns on exit
                            conns.push(thread::spawn(move || {
                                handle_conn(stream, server, stop, served, fault, id, generation)
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
                stop.store(true, Ordering::Relaxed);
                for c in conns {
                    let _ = c.join();
                }
                server
            })
        };
        Ok(NodeServer { id, generation, addr, stop, served, accept: Some(accept) })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Bound address — the actual port when spawned with port 0.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once the node has stopped accepting (shutdown or chaos kill).
    pub fn is_stopped(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Infer requests accepted so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop the node and reap every thread, including the wrapped
    /// session's replicas.  Safe (and required) after a chaos kill: the
    /// accept loop has already exited, so this just joins and tears
    /// down.  Returns the number of accepted requests.
    pub fn shutdown(mut self) -> u64 {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            if let Ok(server) = h.join() {
                if let Ok(server) = Arc::try_unwrap(server) {
                    let _ = server.shutdown();
                }
            }
        }
        self.served.load(Ordering::Relaxed)
    }
}
