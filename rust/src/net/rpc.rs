//! Length-prefixed framing over blocking `TcpStream`s.
//!
//! On the wire a message is `u32` little-endian payload length followed
//! by the payload (`Frame::encode`).  Two read paths are provided:
//!
//! * [`read_frame`] — plain blocking read for client handshakes and
//!   reader threads that own the socket until it closes.
//! * [`read_frame_interruptible`] — for node-side connection handlers:
//!   the socket has a short read timeout and the loop polls a stop flag
//!   between partial reads, so a node can shut down (or be chaos-killed)
//!   without waiting on a silent peer.  Partial prefix/body reads resume
//!   at the saved offset, so a frame split across segments is never
//!   corrupted by a timeout tick.

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{bail, ensure, Result};

use super::wire::Frame;

/// Upper bound on a single frame payload; anything larger is treated as
/// stream corruption (an affinity snapshot for the largest profiled
/// planner is well under 1 MiB).
pub const MAX_FRAME: usize = 16 << 20;

/// Serialize `f` and write it with a `u32` length prefix.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> Result<()> {
    let mut buf = Vec::with_capacity(64);
    f.encode(&mut buf);
    ensure!(buf.len() <= MAX_FRAME, "frame of {} bytes exceeds MAX_FRAME", buf.len());
    w.write_all(&(buf.len() as u32).to_le_bytes())?;
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Blocking read of one length-prefixed frame.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    ensure!(len <= MAX_FRAME, "frame length {len} exceeds MAX_FRAME");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Frame::decode(&buf)
}

/// Outcome of a stop-aware frame read.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete frame arrived.
    Frame(Frame),
    /// The peer closed the connection cleanly.
    Eof,
    /// The stop flag was raised while waiting.
    Stopped,
}

enum Fill {
    Done,
    Eof,
    Stopped,
}

/// Fill `buf` completely, retrying timeout ticks while `stop` is low.
fn read_full(r: &mut impl Read, buf: &mut [u8], stop: &AtomicBool) -> Result<Fill> {
    let mut off = 0;
    while off < buf.len() {
        if stop.load(Ordering::Relaxed) {
            return Ok(Fill::Stopped);
        }
        match r.read(&mut buf[off..]) {
            Ok(0) => return Ok(Fill::Eof),
            Ok(n) => off += n,
            Err(e)
                if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut | io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Fill::Done)
}

/// Read one frame from a stream whose read timeout is already set,
/// checking `stop` between partial reads.  EOF after a partial frame is
/// reported as `Eof` (the peer died mid-frame; nothing to salvage).
pub fn read_frame_interruptible(r: &mut impl Read, stop: &AtomicBool) -> Result<ReadOutcome> {
    let mut len4 = [0u8; 4];
    match read_full(r, &mut len4, stop)? {
        Fill::Done => {}
        Fill::Eof => return Ok(ReadOutcome::Eof),
        Fill::Stopped => return Ok(ReadOutcome::Stopped),
    }
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME");
    }
    let mut buf = vec![0u8; len];
    match read_full(r, &mut buf, stop)? {
        Fill::Done => Ok(ReadOutcome::Frame(Frame::decode(&buf)?)),
        Fill::Eof => Ok(ReadOutcome::Eof),
        Fill::Stopped => Ok(ReadOutcome::Stopped),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_through_a_byte_pipe() {
        let frames = vec![
            Frame::Heartbeat { seq: 1 },
            Frame::Infer { seq: 2, dense: vec![1.0; 6], sparse: vec![3; 7], label: 0.0 },
            Frame::Shutdown,
        ];
        let mut pipe = Vec::new();
        for f in &frames {
            write_frame(&mut pipe, f).unwrap();
        }
        let mut r = &pipe[..];
        for f in &frames {
            assert_eq!(*f, read_frame(&mut r).unwrap());
        }
        assert!(read_frame(&mut r).is_err(), "read past the last frame");
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut pipe = Vec::new();
        pipe.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        pipe.extend_from_slice(&[0u8; 16]);
        assert!(read_frame(&mut &pipe[..]).is_err());
    }

    #[test]
    fn interruptible_read_sees_stop_and_eof() {
        let stop = AtomicBool::new(false);
        // clean EOF on an empty stream
        let empty: &[u8] = &[];
        assert!(matches!(
            read_frame_interruptible(&mut { empty }, &stop).unwrap(),
            ReadOutcome::Eof
        ));
        // stop flag wins before any byte is consumed
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let mut pipe = Vec::new();
        write_frame(&mut pipe, &Frame::Heartbeat { seq: 5 }).unwrap();
        assert!(matches!(
            read_frame_interruptible(&mut &pipe[..], &stop).unwrap(),
            ReadOutcome::Stopped
        ));
        // with the flag low the same bytes decode normally
        stop.store(false, std::sync::atomic::Ordering::Relaxed);
        assert!(matches!(
            read_frame_interruptible(&mut &pipe[..], &stop).unwrap(),
            ReadOutcome::Frame(Frame::Heartbeat { seq: 5 })
        ));
    }
}
