//! Client side of the multi-node tier: a ring-backed `RoutePolicy`
//! promoted to cross-process routing, plus the `NetClient` connection
//! manager and the network open-loop load generator.
//!
//! `RemoteRouter` keeps the intra-process `RoutePolicy` surface — node
//! slots are just indices into a client-side `QueueDepths` whose gauges
//! now mean "requests in flight to that node" — so the serving stack's
//! routing abstractions carry over unchanged.  On top of that,
//! `NetClient` adds what the network makes necessary:
//!
//! * **liveness** — a reader thread per node marks its slot dead on
//!   EOF/error; `sweep` also evicts nodes whose replies *and* heartbeat
//!   acks have gone silent past `hang_timeout` while work is queued;
//! * **re-route on death** — an evicted node's in-flight requests drain
//!   to the front of a pending queue in sequence order (the same
//!   discipline the in-process supervisor uses for a dead replica's
//!   queue) and re-dispatch to surviving nodes;
//! * **rejoin** — an optional respawn callback maps a dead slot to a
//!   fresh address; on reconnect the node's ring points are restored
//!   (snap-back) and its slot is marked alive again;
//! * **backpressure** — a slot at `max_outstanding` in-flight requests
//!   overflows to the least-loaded live node; with every node saturated
//!   the dispatcher sweeps and waits instead of growing socket buffers.

// lint:allow-file(D2) socket liveness (heartbeats, hang eviction, drain
// deadlines) is wall-clock by nature; no verdict bit depends on these
// reads and the loopback equivalence tests pin the results bit-identical

use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::access::AffinityMap;
use crate::powersys::dataset::Sample;
use crate::serve::load::{OpenLoopCfg, OpenLoopReport};
use crate::serve::{QueueDepths, RoutePolicy};
use crate::util::prng::Rng;
use crate::util::sync::{lock_recover, wait_timeout_recover};

use super::ring::HashRing;
use super::rpc::{read_frame, write_frame};
use super::wire::{Frame, NodeGauge};

/// Respawn callback: given a dead slot, optionally return the address of
/// a replacement node to rejoin in its place.
pub type RespawnFn<'a> = dyn FnMut(usize) -> Option<String> + 'a;

/// `RoutePolicy` over a consistent-hash ring of nodes.  Slot indices
/// into the client-side `QueueDepths` double as ring node ids.
pub struct RemoteRouter {
    affinity: AffinityMap,
    ring: Mutex<HashRing>,
    slots: usize,
}

impl RemoteRouter {
    pub fn new(affinity: AffinityMap, slots: usize, vnodes: usize) -> RemoteRouter {
        let ids: Vec<u64> = (0..slots as u64).collect();
        RemoteRouter { affinity, ring: Mutex::new(HashRing::with_nodes(vnodes, &ids)), slots }
    }

    /// Ring owner for a sparse vector's affinity key (ignoring liveness).
    pub fn pick(&self, sparse: &[u64]) -> usize {
        let key = self.affinity.key(sparse);
        match lock_recover(&self.ring).node_for(key) {
            Some(n) => n as usize,
            None => (key % self.slots.max(1) as u64) as usize,
        }
    }

    /// Remove a node's ring points; its keys spill to the survivors.
    pub fn evict(&self, slot: usize) -> bool {
        lock_recover(&self.ring).remove(slot as u64)
    }

    /// Restore a node's ring points; its keys snap back.
    pub fn rejoin(&self, slot: usize) -> bool {
        lock_recover(&self.ring).add(slot as u64)
    }

    pub fn epoch(&self) -> u64 {
        lock_recover(&self.ring).epoch()
    }

    pub fn ring_len(&self) -> usize {
        lock_recover(&self.ring).len()
    }

    pub fn affinity(&self) -> &AffinityMap {
        &self.affinity
    }
}

impl RoutePolicy for RemoteRouter {
    fn name(&self) -> &'static str {
        "ring_affinity"
    }

    fn route(&self, sample: &Sample, depths: &QueueDepths) -> usize {
        let want = self.pick(&sample.sparse) % depths.len().max(1);
        depths.first_alive_from(want)
    }
}

/// Reply delivered back from a node, stamped with client receive time.
#[derive(Clone, Copy, Debug)]
pub struct RemoteReply {
    pub prob: f32,
    pub latency: Duration,
    pub queue_delay: Duration,
    pub shed: bool,
    pub node: usize,
    pub at: Instant,
}

struct ReplySink {
    replies: Mutex<HashMap<u64, RemoteReply>>,
    cv: Condvar,
}

struct Conn {
    writer: TcpStream,
    /// seq → sample index, for requeue on death.
    outstanding: Arc<Mutex<HashMap<u64, usize>>>,
    dead: Arc<AtomicBool>,
    /// Micros since client epoch of the last frame from this node.
    last_seen: Arc<AtomicU64>,
    gauge: Arc<Mutex<NodeGauge>>,
    reader: Option<thread::JoinHandle<()>>,
}

struct Slot {
    addr: String,
    conn: Option<Conn>,
}

pub struct NetClient {
    router: Arc<RemoteRouter>,
    depths: Arc<QueueDepths>,
    slots: Vec<Slot>,
    sink: Arc<ReplySink>,
    epoch: Instant,
    affinity_json: String,
    max_outstanding: usize,
    hang_timeout: Duration,
    heartbeat_every: Duration,
    last_heartbeat: Vec<Instant>,
    next_seq: u64,
    /// Requests drained from dead nodes, awaiting re-dispatch in
    /// original sequence order.
    pending: VecDeque<(u64, usize)>,
    /// Requests that could not be delivered to any live node.
    pub undeliverable: usize,
    pub evictions: u64,
    pub rejoins: u64,
}

impl NetClient {
    /// Connect to every address, shipping the affinity snapshot in the
    /// `Join` handshake; nodes that cannot parse it refuse the join.
    pub fn connect(
        affinity: AffinityMap,
        addrs: &[String],
        vnodes: usize,
        max_outstanding: usize,
    ) -> Result<NetClient> {
        ensure!(!addrs.is_empty(), "need at least one node address");
        let affinity_json = affinity.to_json().to_string();
        let router = Arc::new(RemoteRouter::new(affinity, addrs.len(), vnodes));
        let mut client = NetClient {
            router,
            depths: Arc::new(QueueDepths::new(addrs.len())),
            slots: addrs.iter().map(|a| Slot { addr: a.clone(), conn: None }).collect(),
            sink: Arc::new(ReplySink { replies: Mutex::new(HashMap::new()), cv: Condvar::new() }),
            epoch: Instant::now(),
            affinity_json,
            max_outstanding: max_outstanding.max(1),
            hang_timeout: Duration::from_millis(500),
            heartbeat_every: Duration::from_millis(50),
            last_heartbeat: vec![Instant::now(); addrs.len()],
            next_seq: 0,
            pending: VecDeque::new(),
            undeliverable: 0,
            evictions: 0,
            rejoins: 0,
        };
        for i in 0..client.slots.len() {
            client
                .connect_slot(i)
                .with_context(|| format!("join node {i} at {}", client.slots[i].addr))?;
        }
        Ok(client)
    }

    /// Heartbeat cadence and silent-node eviction threshold.
    pub fn timeouts(mut self, heartbeat_every: Duration, hang_timeout: Duration) -> NetClient {
        self.heartbeat_every = heartbeat_every;
        self.hang_timeout = hang_timeout;
        self
    }

    pub fn router(&self) -> &RemoteRouter {
        &self.router
    }

    pub fn nodes(&self) -> usize {
        self.slots.len()
    }

    pub fn live_nodes(&self) -> usize {
        self.depths.live_count()
    }

    /// Last gauge piggybacked by a node, if it ever replied.
    pub fn gauge(&self, slot: usize) -> Option<NodeGauge> {
        self.slots[slot].conn.as_ref().map(|c| *lock_recover(&c.gauge))
    }

    fn connect_slot(&mut self, i: usize) -> Result<()> {
        let mut stream = TcpStream::connect(&self.slots[i].addr)?;
        stream.set_nodelay(true)?;
        write_frame(&mut stream, &Frame::Join { node: i as u64, affinity: self.affinity_json.clone() })?;
        match read_frame(&mut stream)? {
            Frame::JoinAck { ok: true, .. } => {}
            Frame::JoinAck { ok: false, .. } => bail!("node rejected affinity snapshot"),
            f => bail!("expected JoinAck, got {f:?}"),
        }
        let outstanding = Arc::new(Mutex::new(HashMap::new()));
        let dead = Arc::new(AtomicBool::new(false));
        let last_seen = Arc::new(AtomicU64::new(self.epoch.elapsed().as_micros() as u64));
        let gauge = Arc::new(Mutex::new(NodeGauge::default()));
        let reader = {
            let mut rstream = stream.try_clone()?;
            let outstanding = Arc::clone(&outstanding);
            let dead = Arc::clone(&dead);
            let last_seen = Arc::clone(&last_seen);
            let gauge_slot = Arc::clone(&gauge);
            let sink = Arc::clone(&self.sink);
            let depths = Arc::clone(&self.depths);
            let epoch = self.epoch;
            // lint:allow(D4) per-node reader; reaped (joined) by evict_slot
            thread::spawn(move || {
                loop {
                    let frame = match read_frame(&mut rstream) {
                        Ok(f) => f,
                        Err(_) => break,
                    };
                    last_seen.store(epoch.elapsed().as_micros() as u64, Ordering::Relaxed);
                    match frame {
                        Frame::Reply { seq, prob, latency_ns, queue_delay_ns, shed, gauge } => {
                            *lock_recover(&gauge_slot) = gauge;
                            if lock_recover(&outstanding).remove(&seq).is_some() {
                                depths.leave(i);
                            }
                            let reply = RemoteReply {
                                prob,
                                latency: Duration::from_nanos(latency_ns),
                                queue_delay: Duration::from_nanos(queue_delay_ns),
                                shed,
                                node: i,
                                at: Instant::now(),
                            };
                            lock_recover(&sink.replies).insert(seq, reply);
                            sink.cv.notify_all();
                        }
                        Frame::HeartbeatAck { gauge, .. } => {
                            *lock_recover(&gauge_slot) = gauge;
                        }
                        _ => break, // protocol error: treat as dead
                    }
                }
                dead.store(true, Ordering::Relaxed);
                sink.cv.notify_all();
            })
        };
        self.slots[i].conn =
            Some(Conn { writer: stream, outstanding, dead, last_seen, gauge, reader: Some(reader) });
        self.depths.set_alive(i, true);
        self.last_heartbeat[i] = Instant::now();
        Ok(())
    }

    /// Tear down a slot: mark it dead everywhere, drain its in-flight
    /// requests to the *front* of the pending queue in sequence order
    /// (oldest first — the PR 8 requeue discipline), and reap the reader.
    fn evict_slot(&mut self, slot: usize) {
        let Some(mut conn) = self.slots[slot].conn.take() else { return };
        conn.dead.store(true, Ordering::Relaxed);
        let _ = conn.writer.shutdown(std::net::Shutdown::Both);
        if let Some(h) = conn.reader.take() {
            let _ = h.join();
        }
        // lint:allow(D1) in-flight set is drained once and seq-sorted on the next line
        let mut drained: Vec<(u64, usize)> = lock_recover(&conn.outstanding).drain().collect();
        drained.sort_unstable();
        for _ in &drained {
            self.depths.leave(slot);
        }
        for &(seq, idx) in drained.iter().rev() {
            self.pending.push_front((seq, idx));
        }
        self.depths.set_alive(slot, false);
        self.router.evict(slot);
        self.evictions += 1;
    }

    /// Detect deaths (reader EOF, silent hang), evict, and optionally
    /// rejoin respawned nodes.  Re-dispatch of drained requests happens
    /// in `pump`, which owns the sample slice.
    pub fn sweep(&mut self, mut respawn: Option<&mut RespawnFn<'_>>) {
        for slot in 0..self.slots.len() {
            let Some(conn) = self.slots[slot].conn.as_mut() else { continue };
            if conn.dead.load(Ordering::Relaxed) {
                self.evict_slot(slot);
                continue;
            }
            let in_flight = !lock_recover(&conn.outstanding).is_empty();
            if in_flight {
                let seen = Duration::from_micros(conn.last_seen.load(Ordering::Relaxed));
                let silent = self.epoch.elapsed().saturating_sub(seen);
                if silent > self.hang_timeout {
                    self.evict_slot(slot);
                    continue;
                }
                if self.last_heartbeat[slot].elapsed() > self.heartbeat_every {
                    self.last_heartbeat[slot] = Instant::now();
                    let seq = self.next_seq;
                    if write_frame(&mut conn.writer, &Frame::Heartbeat { seq }).is_err() {
                        self.evict_slot(slot);
                        continue;
                    }
                }
            }
        }
        if let Some(cb) = respawn.as_deref_mut() {
            for slot in 0..self.slots.len() {
                if self.slots[slot].conn.is_some() || self.depths.alive(slot) {
                    continue;
                }
                if let Some(addr) = cb(slot) {
                    self.slots[slot].addr = addr;
                    if self.connect_slot(slot).is_ok() {
                        self.router.rejoin(slot);
                        self.rejoins += 1;
                    }
                }
            }
        }
    }

    fn least_loaded_live(&self) -> Option<usize> {
        (0..self.slots.len())
            .filter(|&i| self.depths.alive(i) && self.slots[i].conn.is_some())
            .min_by_key(|&i| self.depths.depth(i))
    }

    /// Dispatch one request, honoring affinity, liveness, and
    /// backpressure.  Fails only when every node is dead.
    fn dispatch(&mut self, seq: u64, idx: usize, sample: &Sample) -> Result<()> {
        loop {
            let Some(fallback) = self.least_loaded_live() else {
                bail!("no live nodes");
            };
            let mut slot = self.router.route(sample, &self.depths);
            if self.slots[slot].conn.is_none() {
                slot = fallback;
            }
            if self.depths.depth(slot) >= self.max_outstanding {
                if self.depths.depth(fallback) >= self.max_outstanding {
                    // every live node saturated: wait for replies
                    thread::sleep(Duration::from_micros(200));
                    self.sweep(None);
                    continue;
                }
                slot = fallback;
            }
            // a slot the ring still names can lose its conn to a
            // concurrent eviction; re-route instead of unwinding
            let Some(conn) = self.slots[slot].conn.as_mut() else {
                self.sweep(None);
                continue;
            };
            lock_recover(&conn.outstanding).insert(seq, idx);
            self.depths.enter(slot);
            match write_frame(&mut conn.writer, &Frame::from_sample(seq, sample)) {
                Ok(()) => return Ok(()),
                Err(_) => {
                    lock_recover(&conn.outstanding).remove(&seq);
                    self.depths.leave(slot);
                    self.evict_slot(slot);
                }
            }
        }
    }

    /// Sweep for deaths, then re-dispatch drained requests in order.
    pub fn pump(&mut self, samples: &[Sample], respawn: Option<&mut RespawnFn<'_>>) {
        self.sweep(respawn);
        while let Some((seq, idx)) = self.pending.pop_front() {
            // a drained request may have been answered just before death
            if lock_recover(&self.sink.replies).contains_key(&seq) {
                continue;
            }
            if self.dispatch(seq, idx, &samples[idx]).is_err() {
                self.undeliverable += 1;
            }
        }
    }

    /// In-flight request count across all nodes plus requeued work.
    pub fn outstanding(&self) -> usize {
        let inflight: usize = self
            .slots
            .iter()
            .filter_map(|s| s.conn.as_ref())
            .map(|c| lock_recover(&c.outstanding).len())
            .sum();
        inflight + self.pending.len()
    }

    /// Closed-loop inference: dispatch and wait for the verdict,
    /// re-routing through node deaths.  30s cap, then an error.
    pub fn infer(&mut self, sample: &Sample) -> Result<RemoteReply> {
        let seq = self.next_seq;
        self.next_seq += 1;
        let one = [sample.clone()];
        self.dispatch(seq, 0, sample)?;
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            {
                let mut replies = lock_recover(&self.sink.replies);
                if let Some(r) = replies.remove(&seq) {
                    return Ok(r);
                }
                let (g, _) =
                    wait_timeout_recover(&self.sink.cv, replies, Duration::from_millis(5));
                drop(g);
            }
            self.pump(&one, None);
            if Instant::now() > deadline {
                bail!("infer seq {seq} timed out");
            }
        }
    }

    /// Send `Leave` and close every connection (replies already read).
    pub fn close(&mut self) {
        for slot in 0..self.slots.len() {
            if let Some(conn) = self.slots[slot].conn.as_mut() {
                let _ = write_frame(&mut conn.writer, &Frame::Leave { node: slot as u64 });
            }
            self.evict_slot(slot);
        }
    }
}

/// Multi-node open-loop result: the familiar per-stream report plus
/// ring/recovery accounting.
#[derive(Clone, Debug)]
pub struct NetLoopReport {
    pub report: OpenLoopReport,
    pub nodes: usize,
    pub evictions: u64,
    pub rejoins: u64,
    pub ring_epoch: u64,
}

/// Open-loop Poisson generation against a `NetClient` — the network
/// analog of `serve::run_open_loop`, with the same gap formula and seed
/// discipline so offered traffic is comparable across tiers.  The attack
/// window is measured from each request's *scheduled* arrival, so a
/// request re-routed through a node death pays its full recovery time.
pub fn run_open_loop_net(
    client: &mut NetClient,
    samples: &[Sample],
    cfg: &OpenLoopCfg,
    mut respawn: Option<&mut RespawnFn<'_>>,
) -> NetLoopReport {
    let n = samples.len();
    let mut rng = Rng::new(cfg.seed);
    let mut offsets = Vec::with_capacity(n);
    let mut due = 0.0f64;
    for _ in 0..n {
        due += -(1.0 - rng.f64()).ln() / cfg.rate_per_sec;
        offsets.push(due);
    }
    let t0 = Instant::now();
    for i in 0..n {
        let wait = offsets[i] - t0.elapsed().as_secs_f64();
        if wait > 0.0 {
            thread::sleep(Duration::from_secs_f64(wait));
        }
        client.pump(samples, respawn.as_deref_mut());
        let seq = client.next_seq;
        client.next_seq += 1;
        debug_assert_eq!(seq as usize, i);
        if client.dispatch(seq, i, &samples[i]).is_err() {
            client.undeliverable += 1;
        }
    }
    // Drain: every request must come back, requeue, or prove undeliverable.
    let drain_deadline = Instant::now() + Duration::from_secs(30);
    loop {
        client.pump(samples, respawn.as_deref_mut());
        if client.outstanding() == 0 || Instant::now() > drain_deadline {
            break;
        }
        let replies = lock_recover(&client.sink.replies);
        let _ = client.sink.cv.wait_timeout(replies, Duration::from_millis(2));
    }
    let wall = t0.elapsed();

    let replies = lock_recover(&client.sink.replies);
    let mut windows = Vec::new();
    let mut queue = Vec::new();
    let mut service = Vec::new();
    let mut shed = 0usize;
    for (i, off) in offsets.iter().enumerate() {
        let Some(r) = replies.get(&(i as u64)) else { continue };
        if r.shed {
            shed += 1;
            continue;
        }
        let w = (r.at - t0).as_secs_f64() - off;
        windows.push(w.max(0.0));
        queue.push(r.queue_delay.as_secs_f64());
        service.push(r.latency.saturating_sub(r.queue_delay).as_secs_f64());
    }
    drop(replies);
    let dropped = n - windows.len() - shed;
    let report = OpenLoopReport::from_parts(
        n,
        dropped,
        shed,
        client.rejoins,
        wall,
        cfg.rate_per_sec,
        &windows,
        &queue,
        &service,
        client.nodes(),
        "ring_affinity",
    );
    NetLoopReport {
        report,
        nodes: client.nodes(),
        evictions: client.evictions,
        rejoins: client.rejoins,
        ring_epoch: client.router.epoch(),
    }
}
